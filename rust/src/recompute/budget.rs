//! Budgeted planning driver: iterate *select → rewrite → ROAM re-plan*
//! until the plan's total memory (`actual_peak + persistent`) fits a hard
//! budget, or the strategy's eviction reach is exhausted.
//!
//! Each round evicts a growing prefix of the candidate list, rewrites the
//! **original** graph with the union (so chained recomputation wires
//! through clones), and re-runs the full ROAM order+layout pipeline on the
//! augmented graph — the paper's thesis applied: the order/layout substrate
//! is what keeps the high-level technique's overhead low. The driver keeps
//! the best (minimum-total) round seen, so escalating never returns a
//! worse plan than an earlier round or the recompute-free baseline.

use super::rewrite::{rewrite, RewriteResult};
use super::select::{candidates, Candidate, Strategy};
use crate::graph::{Graph, Reachability};
use crate::planner::{roam_plan, ExecutionPlan, RoamCfg};
use crate::sched::sim::{live_at, profile};
use crate::util::Stopwatch;

/// Configuration of the budgeted driver.
#[derive(Clone, Debug)]
pub struct RecomputeCfg {
    /// Candidate selection strategy.
    pub strategy: Strategy,
    /// ROAM planner configuration used for every (re-)planning round.
    pub roam: RoamCfg,
    /// Maximum select→rewrite→plan rounds.
    pub max_rounds: usize,
    /// Eviction-prefix growth factor between rounds.
    pub growth: f64,
}

impl Default for RecomputeCfg {
    fn default() -> Self {
        RecomputeCfg {
            strategy: Strategy::Greedy,
            roam: RoamCfg::default(),
            max_rounds: 12,
            growth: 2.0,
        }
    }
}

/// How the budget is specified.
#[derive(Clone, Copy, Debug)]
pub enum BudgetSpec {
    /// Absolute bytes for `actual_peak + persistent`.
    Bytes(u64),
    /// Fraction of the unbudgeted ROAM plan's total (e.g. `0.6`).
    Fraction(f64),
}

/// Result of budgeted planning.
#[derive(Clone, Debug)]
pub struct BudgetedPlan {
    /// The chosen plan; its `stats` carry the recompute overhead counters.
    pub plan: ExecutionPlan,
    /// The graph the plan executes — augmented with recompute ops when any
    /// eviction was applied, otherwise a clone of the input graph.
    pub graph: Graph,
    /// Resolved budget in bytes.
    pub budget: u64,
    /// `actual_peak + persistent` of the recompute-free ROAM baseline.
    pub baseline_total: u64,
    /// Did the chosen plan fit the budget?
    pub met: bool,
    /// Did the driver reach full eviction (every candidate) while trying?
    pub exhausted: bool,
    /// Planning rounds executed (0 = baseline already fit).
    pub rounds: usize,
    /// Evicted-tensor count of the chosen plan.
    pub evicted: usize,
    /// Recompute ops added to the chosen plan's graph.
    pub recompute_ops: usize,
    /// FLOP-proxy overhead: bytes produced by the recompute ops.
    pub recompute_bytes: u64,
}

impl BudgetedPlan {
    /// `actual_peak + persistent` of the chosen plan.
    pub fn total(&self) -> u64 {
        self.plan.total_bytes()
    }
}

/// One escalation round (shared with the tradeoff sweep).
pub(crate) struct Round {
    pub plan: ExecutionPlan,
    pub rewrite: RewriteResult,
}

impl Round {
    pub(crate) fn total(&self) -> u64 {
        self.plan.total_bytes()
    }
}

/// Run escalation rounds with a deterministic eviction-prefix schedule
/// `start_k, ⌈start_k·growth⌉, …, n_candidates`, stopping as soon as
/// `stop(best_total_so_far)` holds. Returns the rounds in execution order.
pub(crate) fn escalate(
    g: &Graph,
    reach: &Reachability,
    cands: &[Candidate],
    cfg: &RecomputeCfg,
    start_k: usize,
    max_rounds: usize,
    stop: impl Fn(u64) -> bool,
) -> Vec<Round> {
    let mut rounds: Vec<Round> = Vec::new();
    if cands.is_empty() {
        return rounds;
    }
    let mut k = start_k.clamp(1, cands.len());
    let mut best = u64::MAX;
    loop {
        let evict: Vec<usize> = cands[..k]
            .iter()
            .flat_map(|c| c.tensors.iter().copied())
            .collect();
        let rw = rewrite(g, reach, &evict);
        let plan = roam_plan(&rw.graph, &cfg.roam);
        best = best.min(plan.total_bytes());
        rounds.push(Round { plan, rewrite: rw });
        if stop(best) || k == cands.len() || rounds.len() >= max_rounds {
            break;
        }
        let grown = ((k as f64) * cfg.growth).ceil() as usize;
        k = grown.max(k + 1).min(cands.len());
    }
    rounds
}

/// Smallest candidate prefix whose (optimistic) estimated saving covers
/// `gap`; at least 1.
pub(crate) fn prefix_for_gap(cands: &[Candidate], gap: u64) -> usize {
    let mut acc = 0u64;
    for (i, c) in cands.iter().enumerate() {
        acc = acc.saturating_add(c.saved);
        if acc >= gap {
            return i + 1;
        }
    }
    cands.len().max(1)
}

/// Recompute-overhead counters attached to a budgeted plan's stats.
struct Overhead {
    rw_ops: usize,
    rw_bytes: u64,
    evicted: usize,
    rounds: usize,
    budget: u64,
    baseline_total: u64,
    met: bool,
}

/// Annotate a plan's stats with the recompute overhead counters the
/// acceptance criteria ask for.
fn annotate(plan: &mut ExecutionPlan, o: &Overhead) {
    if o.rw_ops > 0 {
        plan.planner = format!("{}+rc", plan.planner);
    }
    plan.stats
        .push(("recompute_ops".to_string(), o.rw_ops as f64));
    plan.stats
        .push(("recompute_extra_bytes".to_string(), o.rw_bytes as f64));
    plan.stats
        .push(("recompute_evicted".to_string(), o.evicted as f64));
    plan.stats
        .push(("recompute_rounds".to_string(), o.rounds as f64));
    plan.stats
        .push(("budget_bytes".to_string(), o.budget as f64));
    plan.stats
        .push(("baseline_total_bytes".to_string(), o.baseline_total as f64));
    plan.stats
        .push(("budget_met".to_string(), if o.met { 1.0 } else { 0.0 }));
}

/// Plan `g` under a hard memory budget, trading recompute FLOPs for
/// memory. Always returns the best plan found; check
/// [`BudgetedPlan::met`] for whether the budget was achieved.
pub fn roam_plan_budgeted(g: &Graph, spec: BudgetSpec, cfg: &RecomputeCfg) -> BudgetedPlan {
    let sw = Stopwatch::start();
    let mut base = roam_plan(g, &cfg.roam);
    let baseline_total = base.total_bytes();
    let budget = match spec {
        BudgetSpec::Bytes(b) => b,
        BudgetSpec::Fraction(f) => (baseline_total as f64 * f).floor() as u64,
    };

    if baseline_total <= budget {
        annotate(
            &mut base,
            &Overhead {
                rw_ops: 0,
                rw_bytes: 0,
                evicted: 0,
                rounds: 0,
                budget,
                baseline_total,
                met: true,
            },
        );
        base.planning_secs = sw.secs();
        return BudgetedPlan {
            plan: base,
            graph: g.clone(),
            budget,
            baseline_total,
            met: true,
            exhausted: false,
            rounds: 0,
            evicted: 0,
            recompute_ops: 0,
            recompute_bytes: 0,
        };
    }

    let reach = Reachability::compute(g);
    let prof = profile(g, &base.schedule);
    let mut live_mask = vec![false; g.n_tensors()];
    for t in live_at(g, &base.schedule, prof.peak_step) {
        live_mask[t] = true;
    }
    let cands = candidates(g, &reach, cfg.strategy, &live_mask);

    let gap = baseline_total - budget;
    let start_k = prefix_for_gap(&cands, gap);
    let rounds = escalate(g, &reach, &cands, cfg, start_k, cfg.max_rounds, |best| {
        best <= budget
    });
    let n_rounds = rounds.len();
    let exhausted = rounds
        .last()
        .map(|r| r.rewrite.evicted() == cands.iter().map(|c| c.tensors.len()).sum::<usize>())
        .unwrap_or(cands.is_empty());

    // Choose the minimum-total round; fall back to the baseline if no
    // round beat it (recompute never helps on this graph).
    let best_round = rounds
        .into_iter()
        .min_by_key(|r| (r.total(), r.rewrite.evicted()));
    let (mut plan, graph, rw_ops, rw_bytes, evicted) = match best_round {
        Some(r) if r.total() < baseline_total => {
            let n_ops = r.rewrite.recompute_ops.len();
            let bytes = r.rewrite.recompute_bytes;
            let ev = r.rewrite.evicted();
            (r.plan, r.rewrite.graph, n_ops, bytes, ev)
        }
        _ => (base, g.clone(), 0, 0, 0),
    };
    let met = plan.total_bytes() <= budget;
    annotate(
        &mut plan,
        &Overhead {
            rw_ops,
            rw_bytes,
            evicted,
            rounds: n_rounds,
            budget,
            baseline_total,
            met,
        },
    );
    plan.planning_secs = sw.secs();
    BudgetedPlan {
        plan,
        graph,
        budget,
        baseline_total,
        met,
        exhausted,
        rounds: n_rounds,
        evicted,
        recompute_ops: rw_ops,
        recompute_bytes: rw_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, BuildCfg, ModelKind};

    fn quick_cfg() -> RecomputeCfg {
        RecomputeCfg {
            roam: RoamCfg {
                parallel: false,
                order_max_nodes: 5_000,
                dsa_max_nodes: 5_000,
                ..RoamCfg::default()
            },
            ..RecomputeCfg::default()
        }
    }

    #[test]
    fn loose_budget_returns_baseline() {
        let g = models::build(ModelKind::Alexnet, &BuildCfg::default());
        let r = roam_plan_budgeted(&g, BudgetSpec::Fraction(1.0), &quick_cfg());
        assert!(r.met);
        assert_eq!(r.rounds, 0);
        assert_eq!(r.recompute_ops, 0);
        assert_eq!(r.graph.n_ops(), g.n_ops());
        // Overhead counters are reported even for the baseline.
        assert!(r.plan.stats.iter().any(|(k, _)| k == "recompute_ops"));
        assert!(r
            .plan
            .stats
            .iter()
            .any(|(k, v)| k == "budget_met" && *v == 1.0));
    }

    #[test]
    fn tight_budget_triggers_recompute_on_vit() {
        let g = models::build(ModelKind::Vit, &BuildCfg::default());
        let r = roam_plan_budgeted(&g, BudgetSpec::Fraction(0.9), &quick_cfg());
        assert!(
            r.total() <= r.baseline_total,
            "budgeted {} worse than baseline {}",
            r.total(),
            r.baseline_total
        );
        if r.met {
            assert!(r.total() <= r.budget);
            assert!(r.recompute_ops > 0, "met a sub-baseline budget without recompute");
            assert!(r.recompute_bytes > 0);
        } else {
            assert!(r.exhausted || r.rounds >= quick_cfg().max_rounds);
        }
        // The plan must be valid on the returned (augmented) graph.
        assert!(crate::graph::topo::is_topological(&r.graph, &r.plan.order));
        assert!(crate::graph::validate::validate(&r.graph).is_empty());
    }

    #[test]
    fn prefix_for_gap_is_minimal() {
        use crate::recompute::select::Candidate;
        let c = |saved: u64| Candidate {
            tensors: vec![0],
            saved,
            cost: saved,
            at_peak: false,
        };
        let cands = vec![c(100), c(50), c(10)];
        assert_eq!(prefix_for_gap(&cands, 1), 1);
        assert_eq!(prefix_for_gap(&cands, 100), 1);
        assert_eq!(prefix_for_gap(&cands, 101), 2);
        assert_eq!(prefix_for_gap(&cands, 160), 3);
        assert_eq!(prefix_for_gap(&cands, 10_000), 3);
        assert_eq!(prefix_for_gap(&[], 5), 1);
    }
}

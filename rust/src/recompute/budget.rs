//! Budgeted planning driver: iterate *select → rewrite → ROAM re-plan*
//! until the plan's total memory (`actual_peak + persistent`) fits a hard
//! budget, or the strategy's eviction reach is exhausted.
//!
//! Since the swap subsystem landed, the escalation machinery lives in the
//! technique-generic [`crate::hybrid`] driver; [`roam_plan_budgeted`] is
//! its [`crate::hybrid::Technique::Recompute`] specialisation, kept as
//! the stable recompute-only API (same candidate ranking, prefix
//! schedule, stop rule and best-round selection as the historical
//! driver). Use [`crate::hybrid::roam_plan_hybrid`] directly to mix
//! recomputation with swapping per tensor.

use super::select::Strategy;
use crate::graph::Graph;
use crate::hybrid::{HybridCfg, Technique};
use crate::planner::{ExecutionPlan, RoamCfg};
use crate::swap::cost::CostModel;

pub use crate::hybrid::BudgetSpec;

/// Configuration of the budgeted recompute driver.
#[derive(Clone, Debug)]
pub struct RecomputeCfg {
    /// Candidate selection strategy.
    pub strategy: Strategy,
    /// ROAM planner configuration used for every (re-)planning round.
    pub roam: RoamCfg,
    /// Maximum select→rewrite→plan rounds.
    pub max_rounds: usize,
    /// Eviction-prefix growth factor between rounds.
    pub growth: f64,
}

impl Default for RecomputeCfg {
    fn default() -> Self {
        RecomputeCfg {
            strategy: Strategy::Greedy,
            roam: RoamCfg::default(),
            max_rounds: 12,
            growth: 2.0,
        }
    }
}

impl RecomputeCfg {
    /// The hybrid-driver configuration this recompute config denotes.
    /// Public so CLI call sites can route recompute runs through the
    /// [`crate::planner::PlanRequest`] builder themselves.
    pub fn to_hybrid(&self) -> HybridCfg {
        HybridCfg {
            technique: Technique::Recompute,
            strategy: self.strategy,
            cost: CostModel::default(),
            roam: self.roam.clone(),
            max_rounds: self.max_rounds,
            growth: self.growth,
            // Swap-only knobs: inert for a recompute-only escalation
            // (no swap events to order for, no pairs to slide).
            ..HybridCfg::default()
        }
    }
}

/// Result of budgeted planning.
#[derive(Clone, Debug)]
pub struct BudgetedPlan {
    /// The chosen plan; its `stats` carry the recompute overhead counters.
    pub plan: ExecutionPlan,
    /// The graph the plan executes — augmented with recompute ops when any
    /// eviction was applied, otherwise a clone of the input graph.
    pub graph: Graph,
    /// Resolved budget in bytes.
    pub budget: u64,
    /// `actual_peak + persistent` of the recompute-free ROAM baseline.
    pub baseline_total: u64,
    /// Did the chosen plan fit the budget?
    pub met: bool,
    /// Did the driver reach full eviction (every candidate) while trying?
    pub exhausted: bool,
    /// Planning rounds executed (0 = baseline already fit).
    pub rounds: usize,
    /// Evicted-tensor count of the chosen plan.
    pub evicted: usize,
    /// Recompute ops added to the chosen plan's graph.
    pub recompute_ops: usize,
    /// FLOP-proxy overhead: bytes produced by the recompute ops.
    pub recompute_bytes: u64,
}

impl BudgetedPlan {
    /// `actual_peak + persistent` of the chosen plan.
    pub fn total(&self) -> u64 {
        self.plan.total_bytes()
    }
}

impl From<crate::hybrid::HybridPlan> for BudgetedPlan {
    /// Project the recompute-only view out of a hybrid-driver result.
    fn from(h: crate::hybrid::HybridPlan) -> BudgetedPlan {
        BudgetedPlan {
            plan: h.plan,
            graph: h.graph,
            budget: h.budget,
            baseline_total: h.baseline_total,
            met: h.met,
            exhausted: h.exhausted,
            rounds: h.rounds,
            evicted: h.evicted,
            recompute_ops: h.recompute_ops,
            recompute_bytes: h.recompute_bytes,
        }
    }
}

/// Plan `g` under a hard memory budget, trading recompute FLOPs for
/// memory. Always returns the best plan found; check
/// [`BudgetedPlan::met`] for whether the budget was achieved.
///
/// Legacy wrapper around [`crate::planner::PlanRequest`].
pub fn roam_plan_budgeted(g: &Graph, spec: BudgetSpec, cfg: &RecomputeCfg) -> BudgetedPlan {
    crate::planner::PlanRequest::new(g).hybrid_cfg(cfg.to_hybrid()).budget(spec).run().into_hybrid().into()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, BuildCfg, ModelKind};

    fn quick_cfg() -> RecomputeCfg {
        RecomputeCfg {
            roam: RoamCfg {
                parallel: false,
                order_max_nodes: 5_000,
                dsa_max_nodes: 5_000,
                ..RoamCfg::default()
            },
            ..RecomputeCfg::default()
        }
    }

    #[test]
    fn loose_budget_returns_baseline() {
        let g = models::build(ModelKind::Alexnet, &BuildCfg::default());
        let r = roam_plan_budgeted(&g, BudgetSpec::Fraction(1.0), &quick_cfg());
        assert!(r.met);
        assert_eq!(r.rounds, 0);
        assert_eq!(r.recompute_ops, 0);
        assert_eq!(r.graph.n_ops(), g.n_ops());
        // Overhead counters are reported even for the baseline.
        assert!(r.plan.stats.iter().any(|(k, _)| k == "recompute_ops"));
        assert!(r
            .plan
            .stats
            .iter()
            .any(|(k, v)| k == "budget_met" && *v == 1.0));
    }

    #[test]
    fn tight_budget_triggers_recompute_on_vit() {
        let g = models::build(ModelKind::Vit, &BuildCfg::default());
        let r = roam_plan_budgeted(&g, BudgetSpec::Fraction(0.9), &quick_cfg());
        assert!(
            r.total() <= r.baseline_total,
            "budgeted {} worse than baseline {}",
            r.total(),
            r.baseline_total
        );
        if r.met {
            assert!(r.total() <= r.budget);
            assert!(r.recompute_ops > 0, "met a sub-baseline budget without recompute");
            assert!(r.recompute_bytes > 0);
        } else {
            assert!(r.exhausted || r.rounds >= quick_cfg().max_rounds);
        }
        // A recompute-only driver never inserts swap ops.
        assert!(!r
            .graph
            .ops
            .iter()
            .any(|o| matches!(o.kind, crate::graph::OpKind::SwapOut | crate::graph::OpKind::SwapIn)));
        // The plan must be valid on the returned (augmented) graph.
        assert!(crate::graph::topo::is_topological(&r.graph, &r.plan.order));
        assert!(crate::graph::validate::validate(&r.graph).is_empty());
    }
}

//! Candidate selection: which activations to evict, in what order.
//!
//! Two strategies, mirroring the two classic formulations:
//!
//! * [`Strategy::Greedy`] — Chen et al. (2016)-style max-size /
//!   min-recompute-cost: every evictable tensor is its own candidate,
//!   ranked by whether it is live at the baseline peak, then by
//!   bytes-saved per byte-recomputed.
//! * [`Strategy::SegmentCheckpoint`] — checkpoint at the memory-insensitive
//!   boundaries found by [`crate::segments`] and recompute *within* a
//!   segment: each independent segment's forward activations form one
//!   candidate unit, so the retained set degenerates to the boundary
//!   outputs — exactly the sublinear-memory checkpointing shape, driven by
//!   the same graph division ROAM plans with.
//!
//! Candidates are *units*: the budgeted driver evicts a growing prefix of
//! the returned list, and the rewriter merges the union into one recompute
//! region (so chained evictions recompute through clones, not through
//! retained originals).

use super::rewrite::is_evictable;
use crate::graph::{Graph, Phase, Reachability, TensorId};

/// Selection strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Strategy {
    /// Per-tensor greedy (max size, min recompute cost).
    Greedy,
    /// Per-segment checkpointing at memory-insensitive boundaries.
    SegmentCheckpoint,
}

impl Strategy {
    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "greedy" => Some(Strategy::Greedy),
            "segment" | "segment-checkpoint" => Some(Strategy::SegmentCheckpoint),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Strategy::Greedy => "greedy",
            Strategy::SegmentCheckpoint => "segment",
        }
    }
}

/// One eviction unit.
#[derive(Clone, Debug)]
pub struct Candidate {
    /// Tensors this unit evicts.
    pub tensors: Vec<TensorId>,
    /// Estimated bytes saved (Σ evicted sizes — optimistic; the driver
    /// re-measures with the real simulator every round).
    pub saved: u64,
    /// Estimated recompute cost: Σ bytes produced by the cloned ops.
    pub cost: u64,
    /// Does the unit free anything live at the baseline peak step?
    pub at_peak: bool,
}

/// Enumerate candidates under `strategy`, best first. `live_at_peak` is a
/// per-tensor mask from the baseline plan (see
/// [`crate::sched::sim::live_at`]); pass all-false when unknown.
pub fn candidates(
    g: &Graph,
    reach: &Reachability,
    strategy: Strategy,
    live_at_peak: &[bool],
) -> Vec<Candidate> {
    let live = |t: TensorId| live_at_peak.get(t).copied().unwrap_or(false);
    let mut out = match strategy {
        Strategy::Greedy => {
            let mut v = Vec::new();
            for t in 0..g.n_tensors() {
                if !is_evictable(g, t) {
                    continue;
                }
                let p = g.tensors[t].producer.expect("evictable implies producer");
                let cost: u64 = g.ops[p].outputs.iter().map(|&o| g.tensors[o].size).sum();
                v.push(Candidate {
                    tensors: vec![t],
                    saved: g.tensors[t].size,
                    cost,
                    at_peak: live(t),
                });
            }
            v
        }
        Strategy::SegmentCheckpoint => {
            let bounds = crate::segments::boundaries_core(g, reach);
            let segs = crate::segments::segments(g, reach, &bounds);
            let mut v = Vec::new();
            for seg in &segs {
                let mut tensors: Vec<TensorId> = Vec::new();
                let mut cost = 0u64;
                for &op in &seg.ops {
                    if g.ops[op].phase != Phase::Forward {
                        continue;
                    }
                    let before = tensors.len();
                    for &t in &g.ops[op].outputs {
                        if is_evictable(g, t) {
                            tensors.push(t);
                        }
                    }
                    if tensors.len() > before {
                        // This op will be cloned: count all its outputs.
                        cost += g.ops[op].outputs.iter().map(|&o| g.tensors[o].size).sum::<u64>();
                    }
                }
                if tensors.is_empty() {
                    continue;
                }
                let saved: u64 = tensors.iter().map(|&t| g.tensors[t].size).sum();
                let at_peak = tensors.iter().any(|&t| live(t));
                v.push(Candidate {
                    tensors,
                    saved,
                    cost,
                    at_peak,
                });
            }
            v
        }
    };
    // Rank: peak-relieving first, then saved/cost ratio (cross-multiplied
    // to stay in integers), then raw saving, then id for determinism.
    out.sort_by(|a, b| {
        b.at_peak
            .cmp(&a.at_peak)
            .then_with(|| {
                let lhs = a.saved as u128 * b.cost.max(1) as u128;
                let rhs = b.saved as u128 * a.cost.max(1) as u128;
                rhs.cmp(&lhs)
            })
            .then(b.saved.cmp(&a.saved))
            .then(a.tensors[0].cmp(&b.tensors[0]))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::random::{random_training_graph, RandomGraphCfg};
    use crate::models::{self, BuildCfg, ModelKind};
    use crate::util::quick::forall;

    #[test]
    fn both_strategies_find_candidates_on_models() {
        let g = models::build(ModelKind::Vit, &BuildCfg::default());
        let reach = Reachability::compute(&g);
        let none = vec![false; g.n_tensors()];
        for s in [Strategy::Greedy, Strategy::SegmentCheckpoint] {
            let c = candidates(&g, &reach, s, &none);
            assert!(!c.is_empty(), "{:?} found nothing", s);
            for cand in &c {
                assert!(cand.saved > 0);
                assert!(cand.cost >= cand.saved);
                for &t in &cand.tensors {
                    assert!(is_evictable(&g, t));
                }
            }
        }
    }

    #[test]
    fn candidates_are_disjoint_units() {
        forall("candidate units never overlap", 20, |rng| {
            let fwd_ops = rng.usize_in(4, 15);
            let g = random_training_graph(
                rng,
                &RandomGraphCfg {
                    fwd_ops,
                    ..Default::default()
                },
            );
            let reach = Reachability::compute(&g);
            let none = vec![false; g.n_tensors()];
            for s in [Strategy::Greedy, Strategy::SegmentCheckpoint] {
                let cands = candidates(&g, &reach, s, &none);
                let mut seen = vec![false; g.n_tensors()];
                for c in &cands {
                    for &t in &c.tensors {
                        if seen[t] {
                            return Err(format!("tensor {t} in two {s:?} units"));
                        }
                        seen[t] = true;
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn peak_relief_ranks_first() {
        let g = models::build(ModelKind::Alexnet, &BuildCfg::default());
        let reach = Reachability::compute(&g);
        let mut live = vec![false; g.n_tensors()];
        // Mark one known-evictable tensor as live-at-peak; it must sort
        // into the leading at_peak block.
        let target = (0..g.n_tensors()).find(|&t| is_evictable(&g, t)).unwrap();
        live[target] = true;
        let c = candidates(&g, &reach, Strategy::Greedy, &live);
        assert!(c[0].at_peak);
        assert!(c[0].tensors == vec![target]);
    }

    #[test]
    fn strategy_names_roundtrip() {
        for s in [Strategy::Greedy, Strategy::SegmentCheckpoint] {
            assert_eq!(Strategy::from_name(s.name()), Some(s));
        }
        assert_eq!(Strategy::from_name("nope"), None);
    }
}

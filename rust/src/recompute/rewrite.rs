//! Graph rewriter: clone forward ops into recompute ops scheduled in the
//! backward pass, so the chosen activations can be freed at their last
//! forward use and re-materialised just before their backward consumers.
//!
//! The rewrite is purely structural — it adds ops/tensors and retargets
//! consumer edges — and the memory semantics follow automatically from the
//! liveness rules in [`crate::graph::liveness`]:
//!
//! * an **evicted** tensor loses its backward consumers, so it now dies at
//!   its last forward consumer (the saving);
//! * its **clone**, produced by the cloned op, is born at recompute time
//!   and dies at the original backward consumers (the working set);
//! * **checkpoints** — region inputs produced outside the recompute region
//!   — gain the clone ops as consumers, extending their lifetime into the
//!   backward pass (the retention cost).
//!
//! All three effects are therefore priced exactly by the existing
//! [`crate::sched::sim`] simulator and layout solvers; no special-casing
//! anywhere downstream.
//!
//! Scheduling: every clone op is additionally given a *control input* from
//! a loss-phase anchor op (when one precedes all rewired consumers), which
//! pins recomputation into the backward region for any topological
//! scheduler — the planner's peak-minimising search then places it as late
//! as the backward consumers allow.

use crate::evict::{filter_evictable, find_anchor, retarget_backward};
use crate::graph::{Graph, OpId, Phase, Reachability, TensorClass, TensorId};
use std::collections::HashMap;

pub use crate::evict::is_evictable;

/// Outcome of a rewrite.
#[derive(Clone, Debug)]
pub struct RewriteResult {
    /// The augmented graph (original ops keep their ids; clones appended).
    pub graph: Graph,
    /// Ids of the appended recompute (clone) ops.
    pub recompute_ops: Vec<OpId>,
    /// `(original, clone)` pairs for every evicted tensor.
    pub remap: Vec<(TensorId, TensorId)>,
    /// Σ bytes produced by the recompute ops — the FLOP-proxy overhead.
    pub recompute_bytes: u64,
}

impl RewriteResult {
    /// Number of tensors whose backward consumers were retargeted.
    pub fn evicted(&self) -> usize {
        self.remap.len()
    }
}

/// Rewrite `g` so every tensor in `evict` (silently filtered through
/// [`is_evictable`]) is recomputed for its backward consumers.
///
/// The recompute *region* is the set of producers of the evicted tensors.
/// Clone ops chain through the region: a clone input is the clone of the
/// corresponding tensor when that tensor's producer is itself in the
/// region, and the original tensor (a retained checkpoint) otherwise. The
/// result preserves every [`crate::graph::validate`] invariant —
/// acyclicity included — which the property tests sweep.
///
/// `reach` must be the reachability of `g` (used only for the control-
/// anchor safety check).
pub fn rewrite(g: &Graph, reach: &Reachability, evict: &[TensorId]) -> RewriteResult {
    let evicted = filter_evictable(g, evict);
    if evicted.is_empty() {
        return RewriteResult {
            graph: g.clone(),
            recompute_ops: Vec::new(),
            remap: Vec::new(),
            recompute_bytes: 0,
        };
    }

    let mut in_region = vec![false; g.n_ops()];
    for &t in &evicted {
        in_region[g.tensors[t].producer.expect("evictable implies producer")] = true;
    }

    let mut out = g.clone();
    let mut clone_of: HashMap<TensorId, TensorId> = HashMap::new();
    let mut recompute_ops = Vec::new();
    let mut recompute_bytes = 0u64;

    // Clone region ops in a topological order of the original graph so a
    // clone's clone-inputs already exist when it is created.
    for &v in &crate::graph::topo::program_order(g) {
        if !in_region[v] {
            continue;
        }
        let inputs: Vec<TensorId> = g.ops[v]
            .inputs
            .iter()
            .map(|&u| match g.tensors[u].producer {
                Some(p) if in_region[p] => clone_of[&u],
                _ => u, // checkpoint: retained original
            })
            .collect();
        let specs: Vec<(String, u64, TensorClass)> = g.ops[v]
            .outputs
            .iter()
            .map(|&t| {
                (
                    format!("rc::{}", g.tensors[t].name),
                    g.tensors[t].size,
                    g.tensors[t].class,
                )
            })
            .collect();
        let specs_ref: Vec<(&str, u64, TensorClass)> = specs
            .iter()
            .map(|(n, s, c)| (n.as_str(), *s, *c))
            .collect();
        let (cid, couts) = out.add_op(
            format!("rc::{}", g.ops[v].name),
            g.ops[v].kind,
            Phase::Backward,
            &inputs,
            &specs_ref,
        );
        recompute_ops.push(cid);
        for (&ot, &ct) in g.ops[v].outputs.iter().zip(couts.iter()) {
            clone_of.insert(ot, ct);
            recompute_bytes += g.tensors[ot].size;
        }
    }

    // Retarget the backward consumers of each evicted tensor to its clone.
    let mut remap = Vec::with_capacity(evicted.len());
    for &t in &evicted {
        let ct = clone_of[&t];
        retarget_backward(&mut out, g, t, ct);
        remap.push((t, ct));
    }

    // Control anchor: pin clones after a loss op that provably precedes
    // every retargeted consumer. Acyclic by construction — the anchor
    // strictly precedes all clone-output consumers, and clones have no
    // other successors, so no path can lead back to the anchor.
    if let Some(anchor_tensor) = find_anchor(g, reach, &remap) {
        for &r in &recompute_ops {
            out.add_control_input(r, anchor_tensor);
        }
    }

    debug_assert!(
        crate::graph::validate::validate(&out).is_empty(),
        "recompute rewrite produced an invalid graph"
    );
    RewriteResult {
        graph: out,
        recompute_ops,
        remap,
        recompute_bytes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::graph::{OpKind, Phase, TensorClass};
    use crate::sched::sim::total_peak;
    use crate::sched::Schedule;

    /// fwd chain a→b→loss, backward consumes both activations.
    fn training_chain() -> Graph {
        let mut g = Graph::new("chain");
        let x = g.add_input_tensor("x", 10, TensorClass::Input);
        let (_, t0) = g.add_op(
            "a",
            OpKind::MatMul,
            Phase::Forward,
            &[x],
            &[("act0", 100, TensorClass::Activation)],
        );
        let (_, t1) = g.add_op(
            "b",
            OpKind::MatMul,
            Phase::Forward,
            &[t0[0]],
            &[("act1", 100, TensorClass::Activation)],
        );
        let (_, l) = g.add_op(
            "loss",
            OpKind::Loss,
            Phase::Loss,
            &[t1[0]],
            &[("loss", 4, TensorClass::TempBuffer)],
        );
        g.mark_output(l[0]);
        let (_, d1) = g.add_op(
            "b.bwd",
            OpKind::MatMul,
            Phase::Backward,
            &[t1[0], l[0]],
            &[("dact0", 100, TensorClass::Gradient)],
        );
        let (_, d0) = g.add_op(
            "a.bwd",
            OpKind::MatMul,
            Phase::Backward,
            &[t0[0], d1[0]],
            &[("dx", 10, TensorClass::Gradient)],
        );
        g.mark_output(d0[0]);
        g
    }

    #[test]
    fn evictability_rules() {
        let g = training_chain();
        // act0 (tensor 1) and act1 (tensor 2): both fwd activations with
        // backward consumers... but act1 is ALSO consumed by the loss op.
        assert!(is_evictable(&g, 1));
        assert!(!is_evictable(&g, 2)); // loss consumer pins it
        assert!(!is_evictable(&g, 0)); // graph input
        assert!(!is_evictable(&g, 3)); // loss output (TempBuffer + output)
    }

    #[test]
    fn rewrite_preserves_validity_and_frees_the_original() {
        let g = training_chain();
        let reach = Reachability::compute(&g);
        let r = rewrite(&g, &reach, &[1]);
        assert!(validate(&r.graph).is_empty());
        assert_eq!(r.evicted(), 1);
        assert_eq!(r.recompute_ops.len(), 1);
        assert_eq!(r.recompute_bytes, 100);
        // The original act0 no longer has backward consumers.
        let (orig, clone) = r.remap[0];
        assert!(r.graph.tensors[orig]
            .consumers
            .iter()
            .all(|&c| r.graph.ops[c].phase != Phase::Backward));
        // The clone feeds exactly the old backward consumer (op 4: a.bwd).
        assert_eq!(r.graph.tensors[clone].consumers, vec![4]);
        // The clone op is pinned after the loss via a control input.
        let rc = r.recompute_ops[0];
        assert!(r.graph.ops[rc].inputs.contains(&3), "missing loss anchor");
    }

    #[test]
    fn rewrite_reduces_peak_on_the_chain() {
        // Make act0's retention the bottleneck by padding the chain.
        let g = training_chain();
        let reach = Reachability::compute(&g);
        let r = rewrite(&g, &reach, &[1]);
        // Program order of the augmented graph is a valid schedule; the
        // evicted tensor no longer spans the loss, so the peak drops.
        let base = total_peak(&g, &Schedule::from_order(&crate::graph::topo::program_order(&g)));
        let order = crate::graph::topo::program_order(&r.graph);
        assert!(crate::graph::topo::is_topological(&r.graph, &order));
        let after = total_peak(&r.graph, &Schedule::from_order(&order));
        assert!(
            after <= base,
            "recompute made the chain worse: {after} > {base}"
        );
    }

    #[test]
    fn empty_or_ineligible_evictions_are_identity() {
        let g = training_chain();
        let reach = Reachability::compute(&g);
        let r = rewrite(&g, &reach, &[]);
        assert_eq!(r.graph.n_ops(), g.n_ops());
        assert_eq!(r.evicted(), 0);
        let r = rewrite(&g, &reach, &[2, 0, 3]); // all ineligible
        assert_eq!(r.graph.n_ops(), g.n_ops());
        assert_eq!(r.recompute_bytes, 0);
    }
}

//! Training coordinator: the L3 driver that owns the end-to-end loop.
//!
//! The coordinator loads the AOT artifacts, runs ROAM planning over the
//! *real* lowered train-step graph (reporting the paper's metrics on it),
//! then drives training: synthetic-corpus batches in, loss out, steps
//! timed — with Python nowhere on the path.

pub mod data;
pub mod trainer;

pub use data::Corpus;
pub use trainer::{TrainCfg, Trainer};

//! The training loop driver.
//!
//! Boundary contract with `python/compile/aot.py` (kept deliberately
//! narrow — parameters travel as one flat f32 vector, so the PJRT call has
//! six inputs and five outputs regardless of model size):
//!
//! ```text
//! init()                                  -> (params, m, v, step)
//! train_step(params, m, v, step, tokens, targets)
//!     -> (params', m', v', step', loss)
//! ```

use crate::bail;
use crate::coordinator::data::Corpus;
use crate::runtime::artifact::Artifacts;
use crate::runtime::{LoadedModule, Runtime};
use crate::util::error::{Context, Result};
use crate::util::Stopwatch;

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 200,
            log_every: 10,
            seed: 0,
        }
    }
}

/// One logged point of the loss curve.
#[derive(Clone, Copy, Debug)]
pub struct StepLog {
    pub step: usize,
    pub loss: f32,
    pub secs: f64,
}

/// Training state: compiled modules + current parameters.
pub struct Trainer {
    pub artifacts: Artifacts,
    client: xla::PjRtClient,
    step_mod: LoadedModule,
    /// (params, m, v, step) literals carried across steps.
    state: Vec<xla::Literal>,
    corpus: Corpus,
    batch: usize,
    seq: usize,
    pub history: Vec<StepLog>,
}

impl Trainer {
    /// Load artifacts, compile, and run `init` to create the state.
    pub fn new(rt: &Runtime, artifacts: Artifacts, seed: u64) -> Result<Trainer> {
        let init = rt
            .load_hlo_text(&artifacts.init_path())
            .context("compiling init")?;
        let step_mod = rt
            .load_hlo_text(&artifacts.train_step_path())
            .context("compiling train_step")?;
        let state = init.run(&[]).context("running init")?;
        if state.len() != 4 {
            bail!("init must return (params, m, v, step), got {}", state.len());
        }
        let batch = artifacts.meta.batch;
        let seq = artifacts.meta.seq_len;
        let corpus = Corpus::new(artifacts.meta.vocab, seed);
        Ok(Trainer {
            artifacts,
            client: rt.client.clone(),
            step_mod,
            state,
            corpus,
            batch,
            seq,
            history: Vec::new(),
        })
    }

    /// Run one optimizer step; returns the loss.
    pub fn step(&mut self) -> Result<f32> {
        let sw = Stopwatch::start();
        let (tokens, targets) = self.corpus.next_batch(self.batch, self.seq);
        let tok = xla::Literal::vec1(&tokens)
            .reshape(&[self.batch as i64, self.seq as i64])?;
        let tgt = xla::Literal::vec1(&targets)
            .reshape(&[self.batch as i64, self.seq as i64])?;
        let args: Vec<&xla::Literal> = self
            .state
            .iter()
            .chain([&tok, &tgt])
            .collect();
        let mut out = self.step_mod.run_refs(&self.client, &args)?;
        if out.len() != 5 {
            bail!("train_step must return 5 values, got {}", out.len());
        }
        let loss = out.pop().unwrap().get_first_element::<f32>()?;
        self.state = out;
        let log = StepLog {
            step: self.history.len() + 1,
            loss,
            secs: sw.secs(),
        };
        self.history.push(log);
        Ok(loss)
    }

    /// Drive a full run, printing the loss curve.
    pub fn train(&mut self, cfg: &TrainCfg) -> Result<()> {
        let total = Stopwatch::start();
        for i in 0..cfg.steps {
            let loss = self.step()?;
            if (i + 1) % cfg.log_every == 0 || i == 0 {
                let last = self.history.last().unwrap();
                println!(
                    "step {:>5}  loss {:>8.4}  {:>7.2} ms/step  ({:.1}s elapsed)",
                    i + 1,
                    loss,
                    last.secs * 1e3,
                    total.secs()
                );
            }
        }
        Ok(())
    }

    /// Mean loss over the first / last `k` logged steps (smoke-test metric).
    pub fn loss_drop(&self, k: usize) -> Option<(f32, f32)> {
        if self.history.len() < 2 * k {
            return None;
        }
        let head: f32 =
            self.history[..k].iter().map(|l| l.loss).sum::<f32>() / k as f32;
        let tail: f32 = self.history[self.history.len() - k..]
            .iter()
            .map(|l| l.loss)
            .sum::<f32>()
            / k as f32;
        Some((head, tail))
    }
}

impl LoadedModule {
    /// Execute with borrowed literal args.
    ///
    /// Inputs are staged to device buffers explicitly via
    /// `buffer_from_host_literal` + `execute_b` rather than the crate's
    /// literal-taking `execute`: the latter's C shim leaks its internally
    /// created input buffers (~3× the parameter bytes per step — the 91M-
    /// param trainer OOM-ed at ~30 steps before this change; see
    /// EXPERIMENTS.md §Perf).
    pub fn run_refs(&self, client: &xla::PjRtClient, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let mut bufs = Vec::with_capacity(args.len());
        for lit in args {
            bufs.push(client.buffer_from_host_literal(None, lit)?);
        }
        let outs = self.exe_ref().execute_b::<xla::PjRtBuffer>(&bufs)?;
        drop(bufs);
        let lit = outs[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    use crate::coordinator::data::Corpus;

    #[test]
    fn corpus_feeds_trainer_shapes() {
        let mut c = Corpus::new(512, 3);
        let (t, y) = c.next_batch(4, 64);
        assert_eq!(t.len(), 4 * 64);
        assert_eq!(y.len(), 4 * 64);
    }
}

//! Synthetic tiny-corpus data pipeline.
//!
//! A Markov-chain token stream with a Zipfian unigram distribution: enough
//! structure that a language model's loss *visibly decreases* (bigram
//! structure is learnable), generated deterministically so runs reproduce.

use crate::util::Pcg64;

/// Streaming corpus of token ids in `[0, vocab)`.
pub struct Corpus {
    vocab: usize,
    rng: Pcg64,
    /// Current Markov state.
    state: usize,
    /// Per-state successor table: a few preferred next tokens per state.
    table: Vec<[usize; 4]>,
}

impl Corpus {
    /// Deterministic corpus for a vocab size and seed.
    pub fn new(vocab: usize, seed: u64) -> Corpus {
        assert!(vocab >= 8, "vocab too small");
        let mut rng = Pcg64::new(seed);
        // Each state prefers 4 successors drawn Zipf-ish (low ids common).
        let table = (0..vocab)
            .map(|_| {
                let mut row = [0usize; 4];
                for slot in &mut row {
                    *slot = zipf(&mut rng, vocab);
                }
                row
            })
            .collect();
        Corpus {
            vocab,
            rng,
            state: 0,
            table,
        }
    }

    /// Next token: 80% follow the Markov table, 20% Zipf resample.
    pub fn next_token(&mut self) -> usize {
        let t = if self.rng.chance(0.8) {
            self.table[self.state][self.rng.usize_in(0, 4)]
        } else {
            zipf(&mut self.rng, self.vocab)
        };
        self.state = t;
        t
    }

    /// Fill a batch: `tokens[b*seq + s]`; targets are tokens shifted by 1.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut prev = self.next_token();
            for _ in 0..seq {
                let cur = self.next_token();
                tokens.push(prev as i32);
                targets.push(cur as i32);
                prev = cur;
            }
        }
        (tokens, targets)
    }
}

/// Zipf-ish sampler: token k with probability ∝ 1/(k+1), truncated.
fn zipf(rng: &mut Pcg64, vocab: usize) -> usize {
    // Inverse-CDF approximation: u ~ U(0,1); k = floor(exp(u * ln(V)) - 1).
    let u = rng.f64();
    let k = ((u * (vocab as f64).ln()).exp() - 1.0) as usize;
    k.min(vocab - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Corpus::new(256, 1);
        let mut b = Corpus::new(256, 1);
        let (ta, _) = a.next_batch(2, 16);
        let (tb, _) = b.next_batch(2, 16);
        assert_eq!(ta, tb);
    }

    #[test]
    fn tokens_in_range_and_targets_shifted() {
        let mut c = Corpus::new(64, 7);
        let (tokens, targets) = c.next_batch(3, 32);
        assert_eq!(tokens.len(), 96);
        assert!(tokens.iter().all(|&t| (0..64).contains(&t)));
        // Within a row, target[i] == token[i+1].
        for row in 0..3 {
            for i in 0..31 {
                assert_eq!(targets[row * 32 + i], tokens[row * 32 + i + 1]);
            }
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let mut rng = Pcg64::new(3);
        let lows = (0..2000).filter(|_| zipf(&mut rng, 1024) < 32).count();
        // Low ids must dominate (roughly ln(32)/ln(1024) ≈ 50%).
        assert!(lows > 600, "only {lows} low draws");
    }

    #[test]
    fn bigram_structure_present() {
        // Following the Markov table should make some bigrams much more
        // frequent than chance.
        let mut c = Corpus::new(128, 5);
        let mut counts = std::collections::HashMap::new();
        let mut prev = c.next_token();
        for _ in 0..20_000 {
            let t = c.next_token();
            *counts.entry((prev, t)).or_insert(0usize) += 1;
            prev = t;
        }
        let max = counts.values().copied().max().unwrap();
        assert!(max > 50, "no dominant bigram: max count {max}");
    }
}

//! The `ROAM_FAULTS` / `--faults` specification grammar.
//!
//! A spec is a `;`-separated list of clauses. A clause containing `=`
//! starts a new **rule** binding a failpoint name to an action; a clause
//! without `=` **modifies** the most recent rule:
//!
//! ```text
//! spec     := clause (';' clause)*
//! clause   := rule | modifier
//! rule     := NAME '=' action
//! action   := 'panic' | 'err' | 'delay_ms:' N | 'corrupt'
//! modifier := 'prob:' P ['@' SEED]      # fire with probability P (default 1.0)
//! ```
//!
//! Examples (all valid):
//!
//! ```text
//! leaf_solve=panic
//! leaf_solve=panic;prob:0.3@7
//! cache_disk_write=err;serve_plan=delay_ms:50;prob:0.5@11
//! ```
//!
//! Probabilistic rules draw from a private [`crate::util::rng::Pcg64`]
//! seeded by `SEED`, so a given spec fires at a reproducible subsequence
//! of hits (exactly reproducible under sequential planning; under a
//! parallel pool the *set* of decisions is seed-stable but their
//! assignment to tasks follows arrival order).

use std::fmt;

/// What an armed failpoint does when it fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    /// `panic!` in place — exercises the `catch_unwind` isolation layers.
    Panic,
    /// Return an injected error for the call site's degraded path.
    Err,
    /// Sleep for the given milliseconds, then proceed normally —
    /// exercises deadline degradation without failing anything.
    DelayMs(u64),
    /// Flip one seeded byte of the payload at a corrupt-aware site
    /// ([`crate::faults::maybe_corrupt`]) — silent data corruption, not a
    /// failed call; exercises checksum/quarantine layers. At plain
    /// [`crate::faults::maybe_fail`] sites (no payload to damage) a
    /// `corrupt` rule is inert.
    Corrupt,
}

impl fmt::Display for FaultAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultAction::Panic => write!(f, "panic"),
            FaultAction::Err => write!(f, "err"),
            FaultAction::DelayMs(ms) => write!(f, "delay_ms:{ms}"),
            FaultAction::Corrupt => write!(f, "corrupt"),
        }
    }
}

/// One parsed rule: a failpoint name, an action and a firing probability.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultRule {
    pub name: String,
    pub action: FaultAction,
    /// Firing probability in `[0, 1]` (1.0 = every hit).
    pub prob: f64,
    /// Seed for the rule's private RNG (only consulted when `prob < 1`).
    pub seed: u64,
}

/// A full parsed spec (one or more rules over distinct failpoints).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub rules: Vec<FaultRule>,
}

impl FaultSpec {
    /// Parse a spec string; `Err` carries an operator-readable message.
    pub fn parse(s: &str) -> Result<FaultSpec, String> {
        let mut rules: Vec<FaultRule> = Vec::new();
        for clause in s.split(';').map(str::trim).filter(|c| !c.is_empty()) {
            if let Some((name, action)) = clause.split_once('=') {
                let name = name.trim();
                if name.is_empty() {
                    return Err(format!("empty failpoint name in clause '{clause}'"));
                }
                if rules.iter().any(|r| r.name == name) {
                    return Err(format!("duplicate rule for failpoint '{name}'"));
                }
                rules.push(FaultRule {
                    name: name.to_string(),
                    action: parse_action(action.trim())?,
                    prob: 1.0,
                    seed: 0,
                });
            } else if let Some(rest) = clause.strip_prefix("prob:") {
                let rule = rules.last_mut().ok_or_else(|| {
                    format!("modifier '{clause}' must follow a NAME=ACTION rule")
                })?;
                let (p_str, seed) = match rest.split_once('@') {
                    Some((p, s)) => (
                        p,
                        s.trim()
                            .parse::<u64>()
                            .map_err(|_| format!("bad seed in '{clause}' (want an integer)"))?,
                    ),
                    None => (rest, 0u64),
                };
                let p: f64 = p_str
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad probability in '{clause}' (want a number)"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("probability {p} in '{clause}' is outside [0, 1]"));
                }
                rule.prob = p;
                rule.seed = seed;
            } else {
                return Err(format!(
                    "unrecognised clause '{clause}' \
                     (want NAME=panic|err|delay_ms:N or prob:P@SEED)"
                ));
            }
        }
        if rules.is_empty() {
            return Err("empty fault spec".to_string());
        }
        Ok(FaultSpec { rules })
    }
}

impl fmt::Display for FaultSpec {
    /// Canonical re-rendering; `parse(format!("{spec}"))` round-trips.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, r) in self.rules.iter().enumerate() {
            if i > 0 {
                write!(f, ";")?;
            }
            write!(f, "{}={}", r.name, r.action)?;
            if r.prob < 1.0 {
                write!(f, ";prob:{}@{}", r.prob, r.seed)?;
            }
        }
        Ok(())
    }
}

fn parse_action(s: &str) -> Result<FaultAction, String> {
    match s {
        "panic" => Ok(FaultAction::Panic),
        "err" => Ok(FaultAction::Err),
        "corrupt" => Ok(FaultAction::Corrupt),
        _ => match s.strip_prefix("delay_ms:") {
            Some(n) => n
                .trim()
                .parse::<u64>()
                .map(FaultAction::DelayMs)
                .map_err(|_| format!("bad delay in 'delay_ms:{n}' (want milliseconds)")),
            None => Err(format!(
                "unknown action '{s}' (want panic|err|delay_ms:N|corrupt)"
            )),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_single_rule() {
        let s = FaultSpec::parse("leaf_solve=panic").unwrap();
        assert_eq!(s.rules.len(), 1);
        assert_eq!(s.rules[0].name, "leaf_solve");
        assert_eq!(s.rules[0].action, FaultAction::Panic);
        assert_eq!(s.rules[0].prob, 1.0);
    }

    #[test]
    fn parses_issue_example() {
        // The leaf_solve half of the spec the chaos-smoke CI job uses.
        let s = FaultSpec::parse("leaf_solve=panic;prob:0.3@7").unwrap();
        assert_eq!(s.rules.len(), 1);
        assert_eq!(s.rules[0].prob, 0.3);
        assert_eq!(s.rules[0].seed, 7);
    }

    #[test]
    fn parses_multi_rule_with_delay() {
        let s =
            FaultSpec::parse("cache_disk_write=err; serve_plan=delay_ms:50 ;prob:0.5@11").unwrap();
        assert_eq!(s.rules.len(), 2);
        assert_eq!(s.rules[0].action, FaultAction::Err);
        assert_eq!(s.rules[0].prob, 1.0);
        assert_eq!(s.rules[1].action, FaultAction::DelayMs(50));
        assert_eq!(s.rules[1].prob, 0.5);
        assert_eq!(s.rules[1].seed, 11);
    }

    #[test]
    fn parses_corrupt_action() {
        let s = FaultSpec::parse("cache_disk_write=corrupt;prob:0.5@3").unwrap();
        assert_eq!(s.rules[0].action, FaultAction::Corrupt);
        assert_eq!(s.rules[0].prob, 0.5);
        assert_eq!(s.rules[0].seed, 3);
    }

    #[test]
    fn display_round_trips() {
        for raw in [
            "leaf_solve=panic",
            "leaf_solve=panic;prob:0.3@7",
            "a=err;b=delay_ms:9;prob:0.25@3;c=panic",
            "cache_disk_write=corrupt;prob:0.5@3",
        ] {
            let s = FaultSpec::parse(raw).unwrap();
            let again = FaultSpec::parse(&format!("{s}")).unwrap();
            assert_eq!(s, again, "round-trip failed for {raw:?}");
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            ";;",
            "prob:0.5",                  // modifier before any rule
            "leaf_solve=teleport",       // unknown action
            "leaf_solve=delay_ms:abc",   // bad delay
            "=panic",                    // empty name
            "a=panic;prob:1.5",          // probability out of range
            "a=panic;prob:x@1",          // bad probability
            "a=panic;prob:0.5@x",        // bad seed
            "a=panic;a=err",             // duplicate rule
            "just_a_name",               // clause with neither = nor prob:
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }
}

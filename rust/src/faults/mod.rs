//! Deterministic fault injection: compiled-in failpoints, armed by spec.
//!
//! A paper reproduction becomes a production system the day its fallback
//! paths are *exercised*, not merely present. ROAM's stack is full of
//! anytime fallbacks — ASAP leaf orders and LLFB layouts past a deadline,
//! heuristic plans past a serve deadline, memory-only caching past a disk
//! error — but until this module nothing ever forced them. `faults/`
//! makes failure a first-class, reproducible input:
//!
//! * [`spec`] — the `ROAM_FAULTS` / `--faults` grammar
//!   (`name=panic|err|delay_ms:N|corrupt` clauses with `prob:P@seed`
//!   modifiers);
//! * [`registry`] — the armed rule table behind [`maybe_fail`] and
//!   [`maybe_corrupt`], the [`FAILPOINTS`] enumeration, and the
//!   arm/disarm lifecycle.
//!
//! Call sites are fixed (à la `fail-rs` with compiled-in points): each
//! names itself with a `&'static str` and maps `Err(Injected)` onto its
//! local degraded path, while `panic` actions are absorbed by the
//! `catch_unwind` isolation in [`crate::util::pool`] and
//! [`crate::serve::service`]. Disarmed — the default — every failpoint
//! costs one relaxed atomic load, mirroring the [`crate::obs`]
//! discipline, so faults-off plan output is byte-identical to a build
//! without the subsystem (pinned by `tests/fault_props.rs`).

pub mod registry;
pub mod spec;

pub use registry::{
    arm, arm_str, armed, disarm, init, injected_total, maybe_corrupt, maybe_fail, snapshot,
    Injected, FAILPOINTS,
};
pub use spec::{FaultAction, FaultRule, FaultSpec};

//! The armed failpoint registry behind [`maybe_fail`].
//!
//! Disarmed (the default, and the production steady state) a failpoint
//! costs **one relaxed atomic load** — the same discipline as the
//! [`crate::obs`] recorder, so the faults-off planner output is
//! byte-identical to a build without the subsystem. Arming installs the
//! parsed rules behind a mutex consulted only on the armed path.
//!
//! Failpoints are compiled in at fixed sites (à la `fail-rs`) and named
//! in [`FAILPOINTS`], which doubles as the chaos harness's enumeration
//! and as `arm`'s typo guard.

use super::spec::{FaultAction, FaultSpec};
use crate::util::rng::Pcg64;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Every failpoint compiled into the crate, with the degraded path each
/// one exercises:
///
/// | failpoint          | site                                  | degraded path                |
/// |--------------------|---------------------------------------|------------------------------|
/// | `leaf_solve`       | `planner::roam` ordering leaf         | ASAP chunk order             |
/// | `layout_window`    | `planner::roam` DSA window            | LLFB greedy layout           |
/// | `hybrid_round`     | `hybrid` escalation round             | stop with best-so-far rounds |
/// | `serve_plan`       | `serve::service` planning attempt     | retry → heuristic → error    |
/// | `cache_disk_read`  | `serve::cache` disk lookup            | counted miss                 |
/// | `cache_disk_write` | `serve::cache` disk persist           | memory-only insert           |
///
/// `cache_disk_write` is additionally **corrupt-aware**: a `corrupt`
/// rule there flips one seeded byte of the entry payload via
/// [`maybe_corrupt`] instead of failing the write, exercising the
/// checksum → quarantine path rather than the error path.
pub const FAILPOINTS: &[&str] = &[
    "leaf_solve",
    "layout_window",
    "hybrid_round",
    "serve_plan",
    "cache_disk_read",
    "cache_disk_write",
];

static ARMED: AtomicBool = AtomicBool::new(false);
static RULES: Mutex<Vec<RuleState>> = Mutex::new(Vec::new());
/// Total injections fired since process start (armed or not armed —
/// monotone across `arm`/`disarm` cycles, unlike the per-rule counters).
static INJECTED_TOTAL: AtomicU64 = AtomicU64::new(0);

struct RuleState {
    name: String,
    action: FaultAction,
    prob: f64,
    rng: Pcg64,
    hits: u64,
    fired: u64,
}

/// The error an `err`-action failpoint returns; call sites map it onto
/// their local degraded path (it deliberately does not convert into
/// [`crate::util::error::Error`] implicitly — surviving an injection must
/// be a visible decision at the site).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Injected {
    pub name: &'static str,
}

impl fmt::Display for Injected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected fault at failpoint '{}'", self.name)
    }
}

/// Is any fault spec currently armed? (One relaxed load.)
#[inline(always)]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Arm `spec`. Every rule name must be a registered [`FAILPOINTS`] entry
/// — a typo'd spec is an operator error worth failing loudly on, not a
/// silently inert chaos run. Replaces any previously armed spec.
pub fn arm(spec: &FaultSpec) -> Result<(), String> {
    for r in &spec.rules {
        if !FAILPOINTS.contains(&r.name.as_str()) {
            return Err(format!(
                "unknown failpoint '{}' (registered: {})",
                r.name,
                FAILPOINTS.join(", ")
            ));
        }
    }
    let mut rules = RULES.lock().unwrap_or_else(|e| e.into_inner());
    *rules = spec
        .rules
        .iter()
        .map(|r| RuleState {
            name: r.name.clone(),
            action: r.action,
            prob: r.prob,
            rng: Pcg64::new(r.seed ^ 0x9e37_79b9_7f4a_7c15),
            hits: 0,
            fired: 0,
        })
        .collect();
    drop(rules);
    ARMED.store(true, Ordering::Relaxed);
    Ok(())
}

/// Parse and arm a spec string (convenience for CLI/env/tests).
pub fn arm_str(spec: &str) -> Result<(), String> {
    arm(&FaultSpec::parse(spec)?)
}

/// Disarm every failpoint and drop the rules (back to the one-load path).
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
    RULES.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Initialise from the environment and CLI: `--faults SPEC` beats
/// `ROAM_FAULTS`. Returns whether a spec was armed.
pub fn init(cli_spec: Option<&str>) -> Result<bool, String> {
    let env = std::env::var("ROAM_FAULTS").ok();
    let spec = match (cli_spec, env.as_deref()) {
        (Some(s), _) => s.to_string(),
        (None, Some(s)) if !s.trim().is_empty() => s.to_string(),
        _ => return Ok(false),
    };
    arm_str(&spec).map_err(|e| format!("bad fault spec {spec:?}: {e}"))?;
    Ok(true)
}

/// Per-rule `(name, hits, fired)` counters of the armed spec.
pub fn snapshot() -> Vec<(String, u64, u64)> {
    RULES
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .iter()
        .map(|r| (r.name.clone(), r.hits, r.fired))
        .collect()
}

/// Total injections fired since process start (all specs, all cycles).
pub fn injected_total() -> u64 {
    INJECTED_TOTAL.load(Ordering::Relaxed)
}

/// The failpoint primitive. Disarmed: one relaxed load, `Ok(())`.
/// Armed with a matching rule that fires: `panic` panics **after**
/// releasing the registry lock (the isolation layers above catch it),
/// `delay_ms` sleeps then returns `Ok`, `err` returns `Err(Injected)`
/// for the site's degraded path.
pub fn maybe_fail(name: &'static str) -> Result<(), Injected> {
    if !armed() {
        return Ok(());
    }
    let action = {
        let mut rules = RULES.lock().unwrap_or_else(|e| e.into_inner());
        let Some(rs) = rules.iter_mut().find(|r| r.name == name) else {
            return Ok(());
        };
        if rs.action == FaultAction::Corrupt {
            // Corrupt rules damage payloads, not calls: they fire only at
            // corrupt-aware sites via `maybe_corrupt`. Here (before the
            // hit is even counted) they are inert, so a `corrupt` rule on
            // a payload-free failpoint never perturbs anything.
            return Ok(());
        }
        rs.hits += 1;
        let fire = rs.prob >= 1.0 || rs.rng.chance(rs.prob);
        if !fire {
            return Ok(());
        }
        rs.fired += 1;
        rs.action
    };
    INJECTED_TOTAL.fetch_add(1, Ordering::Relaxed);
    crate::obs::metrics::counter_add("faults_injected_total", 1);
    crate::obs::metrics::counter_add(&format!("faults_injected_{name}_total"), 1);
    if crate::obs::span::enabled() {
        crate::obs::span::instant(
            "fault_injected",
            vec![("failpoint", crate::obs::span::ArgVal::Str(name.to_string()))],
        );
    }
    match action {
        FaultAction::Panic => panic!("injected fault at failpoint '{name}'"),
        FaultAction::DelayMs(ms) => {
            std::thread::sleep(std::time::Duration::from_millis(ms));
            Ok(())
        }
        FaultAction::Err => Err(Injected { name }),
        // Unreachable: Corrupt rules bail out above, before firing.
        FaultAction::Corrupt => Ok(()),
    }
}

/// The corrupt-aware failpoint primitive: if a `corrupt` rule is armed
/// on `name` and fires, flip one seeded byte of `bytes` in place and
/// return `true`. Disarmed (or with no matching `corrupt` rule, or an
/// empty payload): one relaxed load / no-op, `false`. Non-`corrupt`
/// rules on the same failpoint are handled by [`maybe_fail`], not here
/// — a site that is both failable and corruptible calls both.
pub fn maybe_corrupt(name: &'static str, bytes: &mut [u8]) -> bool {
    if !armed() || bytes.is_empty() {
        return false;
    }
    let offset = {
        let mut rules = RULES.lock().unwrap_or_else(|e| e.into_inner());
        let Some(rs) = rules
            .iter_mut()
            .find(|r| r.name == name && r.action == FaultAction::Corrupt)
        else {
            return false;
        };
        rs.hits += 1;
        let fire = rs.prob >= 1.0 || rs.rng.chance(rs.prob);
        if !fire {
            return false;
        }
        rs.fired += 1;
        rs.rng.gen_range(bytes.len() as u64) as usize
    };
    bytes[offset] ^= 0xff;
    INJECTED_TOTAL.fetch_add(1, Ordering::Relaxed);
    crate::obs::metrics::counter_add("faults_injected_total", 1);
    crate::obs::metrics::counter_add(&format!("faults_injected_{name}_total"), 1);
    if crate::obs::span::enabled() {
        crate::obs::span::instant(
            "fault_corrupted",
            vec![("failpoint", crate::obs::span::ArgVal::Str(name.to_string()))],
        );
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global; integration-grade properties (and
    // anything arming concurrently with planner runs) live in
    // tests/fault_props.rs behind that file's own lock. Here we pin only
    // cheap invariants that tolerate interleaving with other unit tests,
    // on failpoint names no other test arms.

    #[test]
    fn arm_rejects_unknown_failpoint() {
        let e = arm_str("no_such_point=panic").unwrap_err();
        assert!(e.contains("unknown failpoint"), "{e}");
        assert!(e.contains("leaf_solve"), "message lists the registry: {e}");
    }

    #[test]
    fn failpoints_are_distinct_and_nonempty() {
        let mut names = FAILPOINTS.to_vec();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), FAILPOINTS.len());
        assert!(!FAILPOINTS.is_empty());
    }

    #[test]
    fn injected_display_names_the_failpoint() {
        let i = Injected { name: "leaf_solve" };
        assert_eq!(
            format!("{i}"),
            "injected fault at failpoint 'leaf_solve'"
        );
    }
}

//! Zero-dependency metrics registry: named counters, gauges and
//! log2-bucketed histograms.
//!
//! The registry is global and **off by default** (one relaxed atomic load
//! on the disabled path), enabled by `--metrics` on the CLI or by tests.
//! Producers publish at natural summary points — `planner::evaluate`
//! mirrors `ExecutionPlan::stats` (minus its volatile wall-clock /
//! pool-id keys, so snapshots of identical runs are identical),
//! `serve::ServiceStats` / `serve::CacheStats` mirror their atomic
//! counters on snapshot — rather than replacing those structs, which stay
//! the API-compatible derived views.
//!
//! Two export formats:
//! * [`snapshot_json`] — a stable (BTreeMap-ordered) JSON object,
//! * [`exposition`] — a Prometheus-style `name value` text form.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

static ENABLED: AtomicBool = AtomicBool::new(false);
static REGISTRY: Mutex<BTreeMap<String, Metric>> = Mutex::new(BTreeMap::new());

/// Histogram bucket count: value `v` lands in bucket `⌈log2(v)⌉ + 1`
/// (bucket 0 holds `v ≤ 1`), clamped to the last bucket. 64 buckets cover
/// every u64 byte count and any sane seconds value.
pub const HIST_BUCKETS: usize = 64;

#[derive(Clone, Debug)]
enum Metric {
    Counter(u64),
    Gauge(f64),
    Hist {
        buckets: Box<[u64; HIST_BUCKETS]>,
        count: u64,
        sum: f64,
    },
}

/// Is the registry currently recording?
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the registry on/off (off = every publish is a no-op).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Clear every registered metric.
pub fn reset() {
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

/// Bucket index for a histogram observation.
fn bucket_of(v: f64) -> usize {
    if v.is_nan() || v <= 1.0 {
        return 0;
    }
    let b = v.log2().ceil() as usize + 1;
    b.min(HIST_BUCKETS - 1)
}

/// Add `delta` to the counter `name` (creates it at zero).
pub fn counter_add(name: &str, delta: u64) {
    if !enabled() {
        return;
    }
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Counter(0))
    {
        Metric::Counter(c) => *c += delta,
        _ => panic!("metric {name:?} is not a counter"),
    }
}

/// Read back the counter `name` (None while disabled, for non-counters
/// and for names never touched). Tests and the fault chaos harness use
/// this to assert that degraded paths were actually counted.
pub fn counter_get(name: &str) -> Option<u64> {
    if !enabled() {
        return None;
    }
    let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    match reg.get(name) {
        Some(Metric::Counter(c)) => Some(*c),
        _ => None,
    }
}

/// Set the counter `name` to an absolute value — used when mirroring an
/// external atomic counter (service/cache stats) whose true total already
/// includes earlier increments.
pub fn counter_set(name: &str, value: u64) {
    if !enabled() {
        return;
    }
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    match reg
        .entry(name.to_string())
        .or_insert_with(|| Metric::Counter(0))
    {
        Metric::Counter(c) => *c = value,
        _ => panic!("metric {name:?} is not a counter"),
    }
}

/// Set the gauge `name`.
pub fn gauge_set(name: &str, value: f64) {
    if !enabled() {
        return;
    }
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    reg.insert(name.to_string(), Metric::Gauge(value));
}

/// Record one observation into the log2-bucketed histogram `name`.
pub fn observe(name: &str, value: f64) {
    if !enabled() {
        return;
    }
    let mut reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    match reg.entry(name.to_string()).or_insert_with(|| Metric::Hist {
        buckets: Box::new([0; HIST_BUCKETS]),
        count: 0,
        sum: 0.0,
    }) {
        Metric::Hist {
            buckets,
            count,
            sum,
        } => {
            buckets[bucket_of(value)] += 1;
            *count += 1;
            *sum += value;
        }
        _ => panic!("metric {name:?} is not a histogram"),
    }
}

/// Upper-bound quantile estimates from the log2 histogram `name`:
/// returns `(count, one estimate per q)` where each estimate is the
/// upper bound of the bucket containing the q-th observation (1.0 for
/// the `≤ 1` floor bucket, `2^(k-1)` for bucket `k`). `None` while
/// disabled, for absent names, non-histograms and empty histograms.
/// Coarse by construction (buckets are powers of two) but monotone and
/// cheap — what serve's batch summary derives p50/p95/p99 latency from.
pub fn hist_quantiles(name: &str, qs: &[f64]) -> Option<(u64, Vec<f64>)> {
    if !enabled() {
        return None;
    }
    let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let Some(Metric::Hist { buckets, count, .. }) = reg.get(name) else {
        return None;
    };
    if *count == 0 {
        return None;
    }
    let upper = |i: usize| {
        if i == 0 {
            1.0
        } else {
            (1u64 << (i - 1)) as f64
        }
    };
    let ests = qs
        .iter()
        .map(|&q| {
            // Rank of the q-th observation, 1-based, clamped into range.
            let rank = ((q * *count as f64).ceil() as u64).clamp(1, *count);
            let mut seen = 0u64;
            let mut est = upper(HIST_BUCKETS - 1);
            for (i, &n) in buckets.iter().enumerate() {
                seen += n;
                if seen >= rank {
                    est = upper(i);
                    break;
                }
            }
            est
        })
        .collect();
    Some((*count, ests))
}

/// Stable JSON snapshot of every metric. Counters/gauges are bare
/// numbers; histograms are `{count, sum, buckets: {"le_2^k": n, ...}}`
/// with zero buckets elided.
pub fn snapshot_json() -> Json {
    let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = BTreeMap::new();
    for (name, m) in reg.iter() {
        let v = match m {
            Metric::Counter(c) => Json::Num(*c as f64),
            Metric::Gauge(g) => Json::Num(*g),
            Metric::Hist {
                buckets,
                count,
                sum,
            } => {
                let mut bs = BTreeMap::new();
                for (i, &n) in buckets.iter().enumerate() {
                    if n > 0 {
                        // i=0 → values ≤ 1; i=k → values ≤ 2^(k-1).
                        let label = if i == 0 {
                            "le_1".to_string()
                        } else {
                            format!("le_2^{:02}", i - 1)
                        };
                        bs.insert(label, Json::Num(n as f64));
                    }
                }
                Json::obj(vec![
                    ("count", Json::Num(*count as f64)),
                    ("sum", Json::Num(*sum)),
                    ("buckets", Json::Obj(bs)),
                ])
            }
        };
        out.insert(name.clone(), v);
    }
    Json::Obj(out)
}

/// Prometheus-style text exposition: one `name value` line per
/// counter/gauge, `name_count` / `name_sum` / `name_bucket{le="2^k"}`
/// lines per histogram, sorted by name.
pub fn exposition() -> String {
    let reg = REGISTRY.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = String::new();
    for (name, m) in reg.iter() {
        match m {
            Metric::Counter(c) => out.push_str(&format!("{name} {c}\n")),
            Metric::Gauge(g) => out.push_str(&format!("{name} {g}\n")),
            Metric::Hist {
                buckets,
                count,
                sum,
            } => {
                for (i, &n) in buckets.iter().enumerate() {
                    if n > 0 {
                        let le = if i == 0 {
                            "1".to_string()
                        } else {
                            format!("2^{}", i - 1)
                        };
                        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {n}\n"));
                    }
                }
                out.push_str(&format!("{name}_count {count}\n"));
                out.push_str(&format!("{name}_sum {sum}\n"));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // Global-registry tests mutate shared state; integration-grade
    // determinism properties live in tests/obs_props.rs. Here we only pin
    // the pure pieces plus the disabled no-op path.

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0.0), 0);
        assert_eq!(bucket_of(-3.0), 0);
        assert_eq!(bucket_of(1.0), 0);
        assert_eq!(bucket_of(1.5), 2); // ceil(log2 1.5)=1 → bucket 2
        assert_eq!(bucket_of(2.0), 2);
        assert_eq!(bucket_of(3.0), 3);
        assert_eq!(bucket_of(4.0), 3);
        assert_eq!(bucket_of(1.0e300), HIST_BUCKETS - 1);
        assert_eq!(bucket_of(f64::NAN), 0);
    }

    #[test]
    fn disabled_publishes_are_noops() {
        // Default state is disabled; nothing below may register.
        counter_add("obs_test_never_counter", 3);
        counter_set("obs_test_never_counter2", 9);
        gauge_set("obs_test_never_gauge", 1.5);
        observe("obs_test_never_hist", 2.0);
        let snap = snapshot_json();
        assert!(snap.get("obs_test_never_counter").is_none());
        assert!(snap.get("obs_test_never_counter2").is_none());
        assert!(snap.get("obs_test_never_gauge").is_none());
        assert!(snap.get("obs_test_never_hist").is_none());
        assert!(hist_quantiles("obs_test_never_hist", &[0.5]).is_none());
    }
}

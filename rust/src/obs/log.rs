//! Leveled diagnostics on **stderr only** — stdout belongs to the data
//! protocols (`roam serve`'s JSONL stream, `--out -` plan dumps), so
//! diagnostics must never print there.
//!
//! Level resolution: `--log-level LEVEL` on the CLI beats the `ROAM_LOG`
//! environment variable beats the default (`info`). Use through the
//! [`crate::log_error!`] / [`crate::log_warn!`] / [`crate::log_info!`] /
//! [`crate::log_debug!`] macros, which skip formatting entirely when the
//! level is filtered out.

use std::sync::atomic::{AtomicU8, Ordering};

/// Severity, ordered: a configured level admits itself and everything
/// more severe.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a level name (case-insensitive). `off` suppresses everything.
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// Current max admitted level as a u8 (254 = `off`).
static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

/// `off` sentinel: below Error, admits nothing.
const OFF: u8 = 254;

/// Set the max admitted level.
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Suppress all logging (used by tests pinning byte-exact stderr).
pub fn set_off() {
    MAX_LEVEL.store(OFF, Ordering::Relaxed);
}

/// Initialise from the environment (`ROAM_LOG=debug`), then optionally
/// override from a CLI flag value. Unknown names are ignored except
/// `off`, which suppresses everything.
pub fn init(cli_level: Option<&str>) {
    let pick = |s: &str| {
        if s.eq_ignore_ascii_case("off") {
            MAX_LEVEL.store(OFF, Ordering::Relaxed);
            true
        } else if let Some(l) = Level::parse(s) {
            set_level(l);
            true
        } else {
            false
        }
    };
    if let Ok(env) = std::env::var("ROAM_LOG") {
        pick(&env);
    }
    if let Some(s) = cli_level {
        if !pick(s) {
            eprintln!("[warn] roam: unknown --log-level {s:?} (want error|warn|info|debug|off)");
        }
    }
}

/// Would a message at `level` currently be emitted?
#[inline]
pub fn enabled(level: Level) -> bool {
    (level as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Emit a pre-formatted message (macro back end).
pub fn emit(level: Level, msg: std::fmt::Arguments<'_>) {
    eprintln!("[{}] roam: {}", level.tag(), msg);
}

/// Log an error (always stderr).
#[macro_export]
macro_rules! log_error {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::emit($crate::obs::log::Level::Error, format_args!($($t)*));
        }
    };
}

/// Log a warning.
#[macro_export]
macro_rules! log_warn {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::emit($crate::obs::log::Level::Warn, format_args!($($t)*));
        }
    };
}

/// Log an informational message.
#[macro_export]
macro_rules! log_info {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::emit($crate::obs::log::Level::Info, format_args!($($t)*));
        }
    };
}

/// Log a debug message.
#[macro_export]
macro_rules! log_debug {
    ($($t:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::emit($crate::obs::log::Level::Debug, format_args!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse() {
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("nope"), None);
    }

    #[test]
    fn ordering_admits_more_severe() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }
}

//! Hierarchical span recorder + Chrome trace-event exporter.
//!
//! Design constraints (see the module doc on [`crate::obs`]):
//!
//! * **Disabled must be ~free.** Every public entry point checks one
//!   relaxed `AtomicBool` first and returns an inert guard; no clock read,
//!   no allocation, no lock. The pinned λ=0-style planner outputs are
//!   byte-identical with the recorder compiled in but off.
//! * **Thread-safe without a hot lock.** Events buffer in a
//!   `thread_local!` `Vec` and merge into the global sink when the thread
//!   exits (TLS destructor) or when the buffer fills. [`crate::util::Pool`]
//!   runs workers on `std::thread::scope`, which joins them before `run`
//!   returns — so by the time a caller [`drain`]s, every worker's buffer
//!   has already flushed. The draining thread flushes its own buffer
//!   explicitly.
//! * **Deterministic ordering.** Each event carries a global sequence
//!   number; [`drain`] sorts by it, so two events with the same µs
//!   timestamp never flip between runs of the exporter.
//!
//! The exporter emits the Chrome trace-event JSON array format
//! (`{"traceEvents": [...]}` with `ph: "B"/"E"/"i"` records), loadable in
//! Perfetto or `chrome://tracing`.

use crate::util::json::Json;
use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Flush a thread-local buffer into the sink once it reaches this length
/// (bounds per-thread memory during long solves).
const FLUSH_AT: usize = 8192;

static ENABLED: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Vec<Event>> = Mutex::new(Vec::new());
static NEXT_SEQ: AtomicU64 = AtomicU64::new(0);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static T0: OnceLock<Instant> = OnceLock::new();

/// Chrome trace-event phase of an [`Event`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Span enter (`ph: "B"`).
    Begin,
    /// Span exit (`ph: "E"`).
    End,
    /// Point event (`ph: "i"`).
    Instant,
}

/// A span argument value (number or string).
#[derive(Clone, Debug)]
pub enum ArgVal {
    Num(f64),
    Str(String),
}

impl ArgVal {
    fn to_json(&self) -> Json {
        match self {
            ArgVal::Num(n) => Json::Num(*n),
            ArgVal::Str(s) => Json::Str(s.clone()),
        }
    }
}

/// One recorded trace event.
#[derive(Clone, Debug)]
pub struct Event {
    pub phase: Phase,
    pub name: &'static str,
    /// Microseconds since the recorder's first use (monotonic clock).
    pub ts_us: u64,
    /// Logical thread id (1 = first thread to record, then arrival order).
    pub tid: u64,
    /// Global sequence number — total order across threads.
    pub seq: u64,
    pub args: Vec<(&'static str, ArgVal)>,
}

struct ThreadBuf {
    tid: u64,
    events: Vec<Event>,
}

impl ThreadBuf {
    fn flush(&mut self) {
        if self.events.is_empty() {
            return;
        }
        let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
        sink.append(&mut self.events);
    }
}

impl Drop for ThreadBuf {
    fn drop(&mut self) {
        self.flush();
    }
}

thread_local! {
    static BUF: RefCell<ThreadBuf> = RefCell::new(ThreadBuf {
        tid: NEXT_TID.fetch_add(1, Ordering::Relaxed),
        events: Vec::new(),
    });
}

/// Is the recorder currently on? One relaxed load — the cost every
/// instrumentation site pays when tracing is off.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn the recorder on/off. Turning it on pins the monotonic epoch on
/// first use; turning it off leaves already-buffered events intact.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Drop all recorded events (sink + current thread's buffer) and return
/// the recorder to its pristine state. Tests use this between cases.
pub fn reset() {
    BUF.with(|b| b.borrow_mut().events.clear());
    SINK.lock().unwrap_or_else(|e| e.into_inner()).clear();
}

fn now_us() -> u64 {
    let t0 = T0.get_or_init(Instant::now);
    t0.elapsed().as_micros() as u64
}

fn record(phase: Phase, name: &'static str, args: Vec<(&'static str, ArgVal)>) {
    let ts_us = now_us();
    let seq = NEXT_SEQ.fetch_add(1, Ordering::Relaxed);
    BUF.with(|b| {
        let mut b = b.borrow_mut();
        let tid = b.tid;
        b.events.push(Event {
            phase,
            name,
            ts_us,
            tid,
            seq,
            args,
        });
        if b.events.len() >= FLUSH_AT {
            b.flush();
        }
    });
}

/// RAII span guard: records `Begin` on creation (when enabled) and `End`
/// on drop. Arguments attached via [`SpanGuard::arg`] / [`SpanGuard::arg_str`]
/// ride on the `End` event, so values computed *during* the span (node
/// counts, fallback flags) can be attached after the fact — Perfetto
/// merges B/E args onto the one slice.
pub struct SpanGuard {
    name: &'static str,
    active: bool,
    args: Vec<(&'static str, ArgVal)>,
}

impl SpanGuard {
    /// Attach a numeric argument (no-op when the span is inert).
    pub fn arg(&mut self, key: &'static str, val: f64) -> &mut Self {
        if self.active {
            self.args.push((key, ArgVal::Num(val)));
        }
        self
    }

    /// Attach a string argument (no-op when the span is inert).
    pub fn arg_str(&mut self, key: &'static str, val: &str) -> &mut Self {
        if self.active {
            self.args.push((key, ArgVal::Str(val.to_string())));
        }
        self
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.active {
            record(Phase::End, self.name, std::mem::take(&mut self.args));
        }
    }
}

/// Enter a span. Returns an inert guard (no clock read, no allocation)
/// when the recorder is disabled.
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard {
            name,
            active: false,
            args: Vec::new(),
        };
    }
    record(Phase::Begin, name, Vec::new());
    SpanGuard {
        name,
        active: true,
        args: Vec::new(),
    }
}

/// Record a point event with arguments (incumbent improvements, deadline
/// fallbacks, slide adopt/reject decisions).
#[inline]
pub fn instant(name: &'static str, args: Vec<(&'static str, ArgVal)>) {
    if !enabled() {
        return;
    }
    record(Phase::Instant, name, args);
}

/// Convenience: a numeric-args point event.
#[inline]
pub fn instant_num(name: &'static str, args: &[(&'static str, f64)]) {
    if !enabled() {
        return;
    }
    let args = args.iter().map(|&(k, v)| (k, ArgVal::Num(v))).collect();
    record(Phase::Instant, name, args);
}

/// Merge every thread's flushed events (plus the calling thread's live
/// buffer) and return them ordered by global sequence number. Callers
/// must only drain after parallel work has joined — [`crate::util::Pool`]
/// guarantees that by construction.
pub fn drain() -> Vec<Event> {
    BUF.with(|b| b.borrow_mut().flush());
    let mut events = {
        let mut sink = SINK.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *sink)
    };
    events.sort_by_key(|e| e.seq);
    events
}

/// Render events as a Chrome trace-event JSON document.
pub fn chrome_trace(events: &[Event]) -> Json {
    let trace_events: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut pairs = vec![
                ("name", Json::Str(e.name.to_string())),
                (
                    "ph",
                    Json::Str(
                        match e.phase {
                            Phase::Begin => "B",
                            Phase::End => "E",
                            Phase::Instant => "i",
                        }
                        .to_string(),
                    ),
                ),
                ("ts", Json::Num(e.ts_us as f64)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
            ];
            if e.phase == Phase::Instant {
                // Thread-scoped instant; renders as an arrow in Perfetto.
                pairs.push(("s", Json::Str("t".to_string())));
            }
            if !e.args.is_empty() {
                let args = e
                    .args
                    .iter()
                    .map(|(k, v)| (k.to_string(), v.to_json()))
                    .collect();
                pairs.push(("args", Json::Obj(args)));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::obj(vec![
        ("traceEvents", Json::Arr(trace_events)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

/// Drain the recorder and write a Chrome trace JSON file to `path`.
pub fn write_chrome_trace(path: &str) -> std::io::Result<()> {
    let doc = chrome_trace(&drain());
    std::fs::write(path, doc.pretty() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    // The recorder is global process state, so in-crate unit tests keep to
    // behaviours that are robust under `cargo test`'s default parallelism;
    // the cross-thread nesting and byte-identity properties live in
    // `tests/obs_props.rs`, which serialises access explicitly.

    #[test]
    fn disabled_span_records_nothing_and_is_inert() {
        // Default state is disabled: guards are inert and args are no-ops.
        let mut g = span("never");
        g.arg("n", 1.0).arg_str("s", "x");
        assert!(!g.active);
        assert!(g.args.is_empty());
        drop(g);
        instant_num("never_i", &[("v", 2.0)]);
    }

    #[test]
    fn chrome_trace_shape() {
        let events = vec![
            Event {
                phase: Phase::Begin,
                name: "a",
                ts_us: 1,
                tid: 1,
                seq: 0,
                args: vec![],
            },
            Event {
                phase: Phase::Instant,
                name: "tick",
                ts_us: 2,
                tid: 1,
                seq: 1,
                args: vec![("k", ArgVal::Num(3.0))],
            },
            Event {
                phase: Phase::End,
                name: "a",
                ts_us: 5,
                tid: 1,
                seq: 2,
                args: vec![("label", ArgVal::Str("x".into()))],
            },
        ];
        let doc = chrome_trace(&events);
        let te = doc.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(te.len(), 3);
        assert_eq!(te[0].get("ph").unwrap().as_str(), Some("B"));
        assert_eq!(te[1].get("ph").unwrap().as_str(), Some("i"));
        assert_eq!(te[1].get("s").unwrap().as_str(), Some("t"));
        assert_eq!(te[2].get("ph").unwrap().as_str(), Some("E"));
        assert_eq!(
            te[2].get("args").unwrap().get("label").unwrap().as_str(),
            Some("x")
        );
        // The document round-trips through our own parser.
        assert_eq!(Json::parse(&doc.pretty()).unwrap(), doc);
    }
}

//! Observability substrate: tracing spans, a metrics registry, leveled
//! logging and per-plan memory-timeline profiling — all zero-dependency.
//!
//! ROAM's value proposition is a *measured* one (peak-memory reductions,
//! exposed-transfer seconds, search speedups), so the planner, the hybrid
//! driver and the serving layer need a window better than a flat
//! `Vec<(String, f64)>` and stray `eprintln!`s. This module provides:
//!
//! * [`span`] — a thread-safe, allocation-light hierarchical span recorder
//!   (guard-based enter/exit, monotonic clock, per-thread buffers merged on
//!   drain) with a Chrome trace-event JSON exporter. The resulting
//!   `trace.json` loads directly in Perfetto / `chrome://tracing`. The
//!   recorder is **off by default** and the disabled path is a few-ns
//!   atomic load, so pinned byte-identical plan outputs stay byte-identical.
//! * [`metrics`] — a registry of named counters, gauges and log-bucketed
//!   histograms with a stable JSON snapshot and a text exposition format.
//!   `ExecutionPlan::stats`, the pool fallback counters and the plan-cache
//!   hit/miss counters feed it (stats stays a derived view for API compat).
//! * [`timeline`] — bytes-live-per-timestep profile of a plan with argmax
//!   timestep and per-tensor attribution of the peak, rendered by
//!   `roam inspect` as an ASCII sparkline and exportable as JSON.
//! * [`log`] — leveled stderr-only diagnostics (`ROAM_LOG` env /
//!   `--log-level` flag) so serve's JSONL stdout protocol is never polluted.

pub mod log;
pub mod metrics;
pub mod span;
pub mod timeline;

pub use span::{instant, span, SpanGuard};

//! Observability substrate: tracing spans, a metrics registry, leveled
//! logging and per-plan memory-timeline profiling — all zero-dependency.
//!
//! ROAM's value proposition is a *measured* one (peak-memory reductions,
//! exposed-transfer seconds, search speedups), so the planner, the hybrid
//! driver and the serving layer need a window better than a flat
//! `Vec<(String, f64)>` and stray `eprintln!`s. This module provides:
//!
//! * [`span`] — a thread-safe, allocation-light hierarchical span recorder
//!   (guard-based enter/exit, monotonic clock, per-thread buffers merged on
//!   drain) with a Chrome trace-event JSON exporter. The resulting
//!   `trace.json` loads directly in Perfetto / `chrome://tracing`. The
//!   recorder is **off by default** and the disabled path is a few-ns
//!   atomic load, so pinned byte-identical plan outputs stay byte-identical.
//! * [`metrics`] — a registry of named counters, gauges and log-bucketed
//!   histograms with a stable JSON snapshot and a text exposition format.
//!   `ExecutionPlan::stats`, the pool fallback counters and the plan-cache
//!   hit/miss counters feed it (stats stays a derived view for API compat).
//! * [`timeline`] — bytes-live-per-timestep profile of a plan with argmax
//!   timestep and per-tensor attribution of the peak, rendered by
//!   `roam inspect` as an ASCII sparkline and exportable as JSON.
//! * [`log`] — leveled stderr-only diagnostics (`ROAM_LOG` env /
//!   `--log-level` flag) so serve's JSONL stdout protocol is never polluted.
//! * [`calib`] — trace-driven cost calibration: harvest per-op `op_cost`
//!   instants (drained spans or a saved Chrome trace) into a measured
//!   [`calib::CostTable`] keyed by op kind × byte bucket; an installed
//!   table (`--calib-table`) replaces the FLOP-proxy seconds and modeled
//!   bandwidths everywhere, with counted per-entry fallback.
//! * [`audit`] — plan-vs-actual drift records: re-simulate a plan's
//!   peak/overhead/exposure under the active cost source and report
//!   relative drift per field (`roam audit`, serve `audit` sections,
//!   `plan_drift_*` summary counters).

pub mod audit;
pub mod calib;
pub mod log;
pub mod metrics;
pub mod span;
pub mod timeline;

pub use span::{instant, span, SpanGuard};

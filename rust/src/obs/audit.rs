//! Plan-vs-actual drift auditing: re-simulate a plan under the active
//! cost source and compare against what the planner *predicted*.
//!
//! A plan's stats carry the planner's predictions — `theoretical_peak`,
//! `overhead_secs`, `swap_exposed_secs` — all priced by whatever cost
//! source was active when it planned. [`audit_plan`] independently
//! re-derives each of those from the plan's schedule and augmented
//! graph (memory re-profiled with [`crate::sched::sim::profile`], swap
//! exposure re-serialized with
//! [`crate::swap::cost::plan_swap_overhead`], recompute and codec
//! seconds re-summed from the inserted ops) and reports the relative
//! drift per field.
//!
//! The invariant this buys: auditing a plan under the **same** cost
//! source that planned it reports drift == 0 on every field (pinned in
//! `tests/calib_props.rs`). So non-zero drift means the cost source
//! changed out from under the plan — a newly calibrated table against a
//! proxy-planned cache entry, or a *stale* table against freshly
//! measured traffic. The serve layer audits every response when a table
//! is installed ([`crate::obs::calib`]) and counts threshold crossings
//! (`plan_drift_*` in the batch summary) so mis-pricing shows up in
//! production telemetry, not in an OOM.

use crate::compress::cost::CompressModel;
use crate::graph::{Graph, OpKind};
use crate::obs::{calib, metrics};
use crate::planner::ExecutionPlan;
use crate::swap::cost::{plan_swap_overhead, CostModel};
use crate::swap::rewrite::SwapPair;
use crate::util::json::Json;

/// Schema tag of the audit JSON shape (validated by
/// `python/bench_schema_check.py --audit`).
pub const SCHEMA: &str = "audit-v1";

/// Relative drifts with magnitude below this clamp to exactly 0.0.
/// Absorbs f64 rounding between the planner's accumulation and the
/// audit's re-derivation; real drift (a changed table, a different
/// bandwidth) is orders of magnitude larger.
pub const DRIFT_EPS: f64 = 1e-9;

/// Default relative-drift magnitude above which serve counts a plan as
/// drifted (`plan_drift_exceeded_total`): 1%.
pub const DRIFT_ALERT_REL: f64 = 0.01;

/// One audited quantity: what the planner predicted vs what the
/// re-simulation measured, with the signed relative drift
/// `(actual − predicted) / max(|predicted|, |actual|)`.
#[derive(Clone, Copy, Debug)]
pub struct AuditField {
    pub name: &'static str,
    pub predicted: f64,
    pub actual: f64,
    pub rel_drift: f64,
}

/// Per-plan audit record: the three headline fields plus the identity
/// of the cost source the *audit* priced with.
#[derive(Clone, Debug)]
pub struct AuditRecord {
    /// Fingerprint of the calibration table the audit ran under, when
    /// one was installed (`None` = audited under the pure proxy).
    pub table_fingerprint: Option<u64>,
    /// `peak_bytes`, `overhead_secs`, `exposed_secs` — in that order.
    pub fields: Vec<AuditField>,
}

impl AuditRecord {
    /// Largest |relative drift| across fields — the headline number.
    pub fn max_abs_rel_drift(&self) -> f64 {
        self.fields
            .iter()
            .map(|f| f.rel_drift.abs())
            .fold(0.0, f64::max)
    }

    /// Does any field drift past `rel`?
    pub fn exceeds(&self, rel: f64) -> bool {
        self.max_abs_rel_drift() > rel
    }

    /// JSON form (`audit-v1`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("calibrated", Json::Bool(self.table_fingerprint.is_some())),
            (
                "table_fingerprint",
                match self.table_fingerprint {
                    Some(fp) => Json::Str(format!("{fp:016x}")),
                    None => Json::Null,
                },
            ),
            ("max_abs_rel_drift", Json::Num(self.max_abs_rel_drift())),
            (
                "fields",
                Json::Arr(
                    self.fields
                        .iter()
                        .map(|f| {
                            Json::obj(vec![
                                ("name", Json::Str(f.name.to_string())),
                                ("predicted", Json::Num(f.predicted)),
                                ("actual", Json::Num(f.actual)),
                                ("rel_drift", Json::Num(f.rel_drift)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Mirror the drift into the metrics registry (no-op while metrics
    /// are disabled): one gauge per field (`audit_drift_<name>`), a
    /// log2 histogram of |drift| in parts-per-million
    /// (`audit_drift_ppm` — ppm so sub-1.0 drifts land above the
    /// histogram's `le_1` floor bucket), and a total-audits counter.
    pub fn publish_metrics(&self) {
        if !metrics::enabled() {
            return;
        }
        metrics::counter_add("plan_audits_total", 1);
        for f in &self.fields {
            metrics::gauge_set(&format!("audit_drift_{}", f.name), f.rel_drift);
            metrics::observe("audit_drift_ppm", f.rel_drift.abs() * 1e6);
        }
    }
}

/// Signed relative drift with the [`DRIFT_EPS`] clamp. Symmetric
/// denominator (`max(|p|, |a|)`) so a prediction of 0 against a real
/// actual reads as 100% drift instead of dividing by zero.
fn rel_drift(predicted: f64, actual: f64) -> f64 {
    let denom = predicted.abs().max(actual.abs());
    if denom == 0.0 {
        return 0.0;
    }
    let d = (actual - predicted) / denom;
    if d.abs() < DRIFT_EPS {
        0.0
    } else {
        d
    }
}

/// Reconstruct the swap pairs of an augmented graph from its inserted
/// `SwapOut`/`SwapIn` ops, in ascending out-op order — exactly the
/// order `swap/rewrite.rs` created them in, so re-pricing with
/// [`plan_swap_overhead`] serializes the same job multiset the planner
/// priced.
pub fn extract_swap_pairs(g: &Graph) -> Vec<SwapPair> {
    let mut pairs = Vec::new();
    for op in &g.ops {
        if op.kind != OpKind::SwapOut {
            continue;
        }
        let (Some(&original), Some(&handle)) = (op.inputs.first(), op.outputs.first()) else {
            continue;
        };
        let Some(in_op) = g.tensors[handle]
            .consumers
            .iter()
            .copied()
            .find(|&c| g.ops[c].kind == OpKind::SwapIn)
        else {
            continue;
        };
        let Some(&clone) = g.ops[in_op].outputs.first() else {
            continue;
        };
        pairs.push(SwapPair {
            original,
            handle,
            clone,
            out_op: op.id,
            in_op,
        });
    }
    pairs
}

/// Audit `plan` over its (possibly augmented) graph `g` against the
/// active cost source. `base_ops` is the op count of the pre-rewrite
/// graph — ops at or past it are the rewriter's insertions, which is
/// how recompute clones are told apart from swap/codec machinery.
///
/// Three fields:
/// * `peak_bytes` — predicted `theoretical_peak` vs a fresh
///   [`crate::sched::sim::profile`] of the schedule;
/// * `overhead_secs` — predicted `overhead_secs` stat (0 when absent,
///   e.g. an unbudgeted plan) vs re-derived
///   `recompute + exposed + codec` seconds;
/// * `exposed_secs` — predicted `swap_exposed_secs` stat vs
///   [`plan_swap_overhead`] on the extracted pairs.
pub fn audit_plan(
    g: &Graph,
    base_ops: usize,
    plan: &ExecutionPlan,
    cost: &CostModel,
    compress: &CompressModel,
) -> AuditRecord {
    // Peak: re-profile the schedule.
    let actual_peak = crate::sched::sim::profile(g, &plan.schedule).peak as f64;

    // Exposed: re-serialize the link with the extracted pairs.
    let pairs = extract_swap_pairs(g);
    let actual_exposed = plan_swap_overhead(g, &plan.schedule, cost, &pairs).exposed_secs;

    // Recompute: total cloned output bytes of inserted non-technique
    // ops, priced in one call — mirroring `recompute/rewrite.rs`'s
    // byte counter and `hybrid.rs`'s single `recompute_secs` call.
    let rc_bytes: u64 = g
        .ops
        .iter()
        .skip(base_ops)
        .filter(|op| {
            !matches!(
                op.kind,
                OpKind::SwapOut | OpKind::SwapIn | OpKind::Compress | OpKind::Decompress
            )
        })
        .flat_map(|op| op.outputs.iter().map(|&t| g.tensors[t].size))
        .sum();
    let actual_recompute = if rc_bytes > 0 {
        cost.recompute_secs(rc_bytes)
    } else {
        0.0
    };

    // Codec: round-trip seconds per inserted Compress op, in op order —
    // the same `codec_secs` sum the hybrid driver accumulated.
    let mut actual_codec = 0.0;
    for op in g.ops.iter().skip(base_ops) {
        if op.kind != OpKind::Compress {
            continue;
        }
        if let Some(&orig) = op.inputs.first() {
            let t = &g.tensors[orig];
            let secs = compress.codec_secs(t.class, t.size);
            if secs.is_finite() {
                actual_codec += secs;
            }
        }
    }

    let pred_peak = plan.theoretical_peak as f64;
    let pred_overhead = plan.stat("overhead_secs").unwrap_or(0.0);
    let pred_exposed = plan.stat("swap_exposed_secs").unwrap_or(0.0);
    let actual_overhead = actual_recompute + actual_exposed + actual_codec;

    let field = |name: &'static str, predicted: f64, actual: f64| AuditField {
        name,
        predicted,
        actual,
        rel_drift: rel_drift(predicted, actual),
    };
    AuditRecord {
        table_fingerprint: calib::installed_fingerprint(),
        fields: vec![
            field("peak_bytes", pred_peak, actual_peak),
            field("overhead_secs", pred_overhead, actual_overhead),
            field("exposed_secs", pred_exposed, actual_exposed),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{self, BuildCfg, ModelKind};
    use crate::planner::RoamCfg;

    // Like calib's, these in-crate tests never install a global table —
    // they audit under the proxy, which must self-agree.

    #[test]
    fn rel_drift_shape() {
        assert_eq!(rel_drift(0.0, 0.0), 0.0);
        assert_eq!(rel_drift(100.0, 100.0), 0.0);
        assert_eq!(rel_drift(100.0, 100.0 + 1e-8), 0.0); // clamped
        assert_eq!(rel_drift(0.0, 5.0), 1.0); // zero prediction: 100%
        assert_eq!(rel_drift(5.0, 0.0), -1.0);
        assert!((rel_drift(100.0, 150.0) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn unbudgeted_proxy_plan_audits_clean() {
        let g = models::build(ModelKind::Alexnet, &BuildCfg::default());
        let plan = crate::planner::roam_plan(&g, &RoamCfg::default());
        let rec = audit_plan(
            &g,
            g.n_ops(),
            &plan,
            &CostModel::default(),
            &CompressModel::default(),
        );
        assert_eq!(rec.table_fingerprint, None);
        assert_eq!(rec.fields.len(), 3);
        assert_eq!(
            rec.max_abs_rel_drift(),
            0.0,
            "proxy plan vs proxy audit must agree: {:?}",
            rec.fields
        );
        assert!(!rec.exceeds(DRIFT_ALERT_REL));
        let j = rec.to_json();
        assert_eq!(j.get("schema").and_then(|s| s.as_str()), Some(SCHEMA));
        assert_eq!(j.get("calibrated").and_then(|b| b.as_bool()), Some(false));
    }

    #[test]
    fn budgeted_hybrid_plan_audits_clean_under_same_model() {
        let g = models::build(ModelKind::Mobilenet, &BuildCfg::default());
        let base = crate::planner::roam_plan(&g, &RoamCfg::default());
        let budget = crate::hybrid::BudgetSpec::Fraction(0.8);
        let cfg = crate::hybrid::HybridCfg::default();
        let h = crate::hybrid::roam_plan_hybrid(&g, budget, &cfg);
        assert!(h.plan.total_bytes() <= base.total_bytes());
        let rec = audit_plan(&h.graph, g.n_ops(), &h.plan, &cfg.cost, &cfg.compress);
        assert_eq!(
            rec.max_abs_rel_drift(),
            0.0,
            "hybrid stats vs re-simulation must agree: {:?}",
            rec.fields
        );
    }

    #[test]
    fn stale_cost_model_shows_drift() {
        let g = models::build(ModelKind::Mobilenet, &BuildCfg::default());
        let budget = crate::hybrid::BudgetSpec::Fraction(0.7);
        let cfg = crate::hybrid::HybridCfg::default();
        let h = crate::hybrid::roam_plan_hybrid(&g, budget, &cfg);
        let rec = audit_plan(&h.graph, g.n_ops(), &h.plan, &cfg.cost, &cfg.compress);
        if rec.fields[1].predicted == 0.0 {
            // Budget met without rewrites on this build: nothing to drift.
            return;
        }
        // Audit under a link 4× slower than the one that planned.
        let slow = CostModel {
            pcie_bytes_per_sec: cfg.cost.pcie_bytes_per_sec / 4.0,
            ..cfg.cost
        };
        let drifted = audit_plan(&h.graph, g.n_ops(), &h.plan, &slow, &cfg.compress);
        assert!(
            drifted.max_abs_rel_drift() > 0.0 || h.plan.stat("swap_tensors").unwrap_or(0.0) == 0.0,
            "slower link must surface as drift when swaps exist"
        );
    }
}

//! Trace-driven cost calibration: harvest measured per-op costs into a
//! [`CostTable`] and install it so every modeled-seconds site prices
//! from measurement instead of invented constants.
//!
//! Every objective in the stack — the FLOP-proxy seconds of
//! [`crate::sched::prep::ObjectiveTables`], the PCIe bandwidths of
//! [`crate::swap::cost::CostModel`], the codec throughputs of
//! [`crate::compress::cost::CompressModel`] — is a modeled constant.
//! The `obs/` spans already record what a run *actually* cost, so this
//! module closes the loop:
//!
//! * planning commands emit one [`OP_COST_EVENT`] instant per operator
//!   (kind, bytes, seconds — see [`emit_op_costs`]) into the span
//!   recorder, which `--trace-out` persists as a Chrome trace;
//! * [`harvest_events`] / [`harvest_chrome_trace`] fold those instants
//!   into a [`CostTable`] keyed by **op kind × log2 byte bucket**, each
//!   entry a sorted sample set (median, count and dispersion derive from
//!   it), with lossless JSON round-trip and commutative [`CostTable::merge`]
//!   of multiple runs (`roam calibrate` on the CLI);
//! * [`install`] makes the table process-global: the pricing hooks call
//!   [`lookup`] first and fall back to their modeled constant when the
//!   kind/bucket has no entry — the miss is *counted*
//!   ([`fallbacks`], metric `calib_fallback_total`), never an error.
//!
//! With no table installed every hook is one relaxed atomic load and the
//! plan output is byte-identical to the proxy-only planner (pinned by
//! `tests/calib_props.rs`). [`crate::obs::audit`] re-simulates plans
//! under the installed table to make drift between the two visible.

use crate::graph::{Graph, Op, OpKind};
use crate::obs::span::{self, ArgVal, Event, Phase};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

/// Schema tag of the CostTable JSON shape (validated by
/// `python/bench_schema_check.py --cost-table`).
pub const SCHEMA: &str = "cost-table-v1";

/// Name of the per-operator cost instant the harvesters consume.
pub const OP_COST_EVENT: &str = "op_cost";

static CALIB_ON: AtomicBool = AtomicBool::new(false);
static TABLE: Mutex<Option<(CostTable, u64)>> = Mutex::new(None);
static FALLBACKS: AtomicU64 = AtomicU64::new(0);

/// Stable name of an op kind — the string key measured costs are filed
/// under. Covers every [`OpKind`] variant (the rewriter-inserted
/// `SwapOut`/`SwapIn`/`Compress`/`Decompress` included, so transfer and
/// codec kernels calibrate like any other op).
pub fn kind_name(k: OpKind) -> &'static str {
    match k {
        OpKind::Conv => "Conv",
        OpKind::MatMul => "MatMul",
        OpKind::BatchNorm => "BatchNorm",
        OpKind::LayerNorm => "LayerNorm",
        OpKind::Activation => "Activation",
        OpKind::Softmax => "Softmax",
        OpKind::Pool => "Pool",
        OpKind::Elementwise => "Elementwise",
        OpKind::Reshape => "Reshape",
        OpKind::Reduce => "Reduce",
        OpKind::Embed => "Embed",
        OpKind::Loss => "Loss",
        OpKind::GradAcc => "GradAcc",
        OpKind::OptimStep => "OptimStep",
        OpKind::Input => "Input",
        OpKind::SwapOut => "SwapOut",
        OpKind::SwapIn => "SwapIn",
        OpKind::Compress => "Compress",
        OpKind::Decompress => "Decompress",
        OpKind::Other => "Other",
    }
}

/// Log2 byte-size bucket: 0 holds `bytes ≤ 1`, bucket `b` holds
/// `2^(b-1) < bytes ≤ 2^b`. Costs within one bucket are treated as one
/// population (op cost is near-linear in bytes at this granularity, and
/// bucketing is what lets a table harvested at one size answer for a
/// slightly rescaled model).
pub fn byte_bucket(bytes: u64) -> u32 {
    if bytes <= 1 {
        0
    } else {
        64 - (bytes - 1).leading_zeros()
    }
}

/// Measured cost table: per (op kind, byte bucket), the sorted seconds
/// samples observed. Medians answer lookups; keeping the raw (sorted)
/// samples makes [`CostTable::merge`] commutative and the JSON
/// round-trip lossless.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostTable {
    entries: BTreeMap<(String, u32), Vec<f64>>,
}

impl CostTable {
    /// Number of (kind, bucket) entries.
    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    /// Total sample count across entries.
    pub fn n_samples(&self) -> usize {
        self.entries.values().map(|v| v.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Record one measured sample. Non-finite or negative seconds are
    /// rejected (a poisoned trace must not poison the table).
    pub fn add_sample(&mut self, kind: &str, bytes: u64, secs: f64) {
        if !secs.is_finite() || secs < 0.0 {
            return;
        }
        let v = self
            .entries
            .entry((kind.to_string(), byte_bucket(bytes)))
            .or_default();
        let at = v.partition_point(|&x| x <= secs);
        v.insert(at, secs);
    }

    /// Median measured seconds for (kind, bytes-bucket), when present.
    pub fn secs_for(&self, kind: &str, bytes: u64) -> Option<f64> {
        let v = self.entries.get(&(kind.to_string(), byte_bucket(bytes)))?;
        Some(median(v))
    }

    /// Fold every sample of `other` into `self`. Entries hold sorted
    /// sample multisets, so the merge is commutative and associative —
    /// harvesting N runs in any order yields one table.
    pub fn merge(&mut self, other: &CostTable) {
        for ((kind, bucket), samples) in &other.entries {
            let v = self.entries.entry((kind.clone(), *bucket)).or_default();
            for &s in samples {
                let at = v.partition_point(|&x| x <= s);
                v.insert(at, s);
            }
        }
    }

    /// Content fingerprint (FNV-1a over the canonical entry encoding) —
    /// stamped into plan stats so an audit can tell *which* table priced
    /// a plan.
    pub fn fingerprint(&self) -> u64 {
        let mut buf: Vec<u8> = Vec::new();
        for ((kind, bucket), samples) in &self.entries {
            buf.extend_from_slice(kind.as_bytes());
            buf.push(0);
            buf.extend_from_slice(&bucket.to_le_bytes());
            for s in samples {
                buf.extend_from_slice(&s.to_bits().to_le_bytes());
            }
            buf.push(0xff);
        }
        fnv1a64(&buf)
    }

    /// JSON form: schema tag, per-entry kind/bucket/derived summaries and
    /// the raw sorted samples (the part [`CostTable::from_json`] reads
    /// back), plus the content fingerprint for human consumption.
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|((kind, bucket), samples)| {
                Json::obj(vec![
                    ("kind", Json::Str(kind.clone())),
                    ("bucket", Json::Num(*bucket as f64)),
                    ("count", Json::Num(samples.len() as f64)),
                    ("median_secs", Json::Num(median(samples))),
                    ("dispersion", Json::Num(dispersion(samples))),
                    (
                        "samples",
                        Json::Arr(samples.iter().map(|&s| Json::Num(s)).collect()),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.to_string())),
            ("fingerprint", Json::Str(format!("{:016x}", self.fingerprint()))),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Parse the [`CostTable::to_json`] shape (summaries are re-derived
    /// from the samples; the stored fingerprint is informational).
    pub fn from_json(doc: &Json) -> Result<CostTable, String> {
        match doc.get("schema").and_then(|j| j.as_str()) {
            Some(s) if s == SCHEMA => {}
            other => return Err(format!("cost table schema {other:?}, want {SCHEMA:?}")),
        }
        let entries = doc
            .get("entries")
            .and_then(|j| j.as_arr())
            .ok_or("cost table missing 'entries'")?;
        let mut t = CostTable::default();
        for (i, e) in entries.iter().enumerate() {
            let kind = e
                .get("kind")
                .and_then(|j| j.as_str())
                .ok_or_else(|| format!("entry {i}: missing 'kind'"))?;
            let bucket = e
                .get("bucket")
                .and_then(|j| j.as_u64())
                .ok_or_else(|| format!("entry {i}: missing 'bucket'"))? as u32;
            let samples = e
                .get("samples")
                .and_then(|j| j.as_arr())
                .ok_or_else(|| format!("entry {i}: missing 'samples'"))?;
            let v = t.entries.entry((kind.to_string(), bucket)).or_default();
            for s in samples {
                let s = s.as_f64().ok_or_else(|| format!("entry {i}: bad sample"))?;
                if !s.is_finite() || s < 0.0 {
                    return Err(format!("entry {i}: non-finite/negative sample"));
                }
                v.push(s);
            }
            v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
        }
        t.entries.retain(|_, v| !v.is_empty());
        Ok(t)
    }

    /// Write the table as pretty JSON.
    pub fn save(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty() + "\n")
    }

    /// Load a table from a JSON file.
    pub fn load(path: &str) -> Result<CostTable, String> {
        let text =
            std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        let doc = Json::parse(&text).map_err(|e| format!("parse {path}: {e}"))?;
        CostTable::from_json(&doc)
    }
}

/// Median of a sorted, non-empty sample slice.
fn median(v: &[f64]) -> f64 {
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Relative dispersion: (p90 − p10) / median, 0 for degenerate entries.
/// A large value flags a bucket whose single median is a poor summary
/// (e.g. two op populations sharing a kind).
fn dispersion(v: &[f64]) -> f64 {
    let m = median(v);
    if v.len() < 2 || m <= 0.0 {
        return 0.0;
    }
    let q = |p: f64| v[((p * (v.len() - 1) as f64).round() as usize).min(v.len() - 1)];
    (q(0.9) - q(0.1)) / m
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Install `t` as the process-global calibration table. Every pricing
/// hook ([`lookup`]) answers from it until [`uninstall`].
pub fn install(t: CostTable) {
    let fp = t.fingerprint();
    *TABLE.lock().unwrap_or_else(|e| e.into_inner()) = Some((t, fp));
    FALLBACKS.store(0, Ordering::Relaxed);
    CALIB_ON.store(true, Ordering::Relaxed);
}

/// Remove the installed table and return every hook to its modeled
/// constant (the byte-identical no-table path).
pub fn uninstall() {
    CALIB_ON.store(false, Ordering::Relaxed);
    *TABLE.lock().unwrap_or_else(|e| e.into_inner()) = None;
    FALLBACKS.store(0, Ordering::Relaxed);
}

/// Is a calibration table installed? One relaxed load — the cost every
/// pricing site pays when planning uncalibrated.
#[inline(always)]
pub fn enabled() -> bool {
    CALIB_ON.load(Ordering::Relaxed)
}

/// Fingerprint of the installed table, when one is.
pub fn installed_fingerprint() -> Option<u64> {
    if !enabled() {
        return None;
    }
    TABLE
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .map(|(_, fp)| *fp)
}

/// Calibrated seconds for (kind, bytes), or `None` with the fallback
/// counted when the installed table has no such entry — the caller then
/// uses its modeled constant. `None` without any counting when no table
/// is installed at all.
pub fn lookup(kind: &str, bytes: u64) -> Option<f64> {
    if !enabled() {
        return None;
    }
    let hit = TABLE
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .as_ref()
        .and_then(|(t, _)| t.secs_for(kind, bytes));
    if hit.is_none() {
        FALLBACKS.fetch_add(1, Ordering::Relaxed);
        crate::obs::metrics::counter_add("calib_fallback_total", 1);
    }
    hit
}

/// Number of per-entry fallbacks to the modeled proxy since the table
/// was installed (0 while uninstalled). Also mirrored to the metric
/// `calib_fallback_total`.
pub fn fallbacks() -> u64 {
    FALLBACKS.load(Ordering::Relaxed)
}

/// Modeled (bytes, seconds) of one op under the active cost source —
/// what [`emit_op_costs`] publishes. The byte key matches what each
/// pricing hook will later look up: moved tensor bytes for
/// `SwapOut`/`SwapIn`, the original tensor's bytes for codec kernels,
/// summed output bytes for compute ops.
fn modeled_op_cost(
    g: &Graph,
    op: &Op,
    m: &crate::swap::cost::CostModel,
    cm: &crate::compress::cost::CompressModel,
) -> (u64, f64) {
    match op.kind {
        OpKind::SwapOut => {
            let bytes: u64 = op.inputs.iter().map(|&t| g.tensors[t].size).sum();
            (bytes, m.out_transfer_secs(bytes))
        }
        OpKind::SwapIn => {
            let bytes: u64 = op.outputs.iter().map(|&t| g.tensors[t].size).sum();
            (bytes, m.in_transfer_secs(bytes))
        }
        OpKind::Compress => {
            let t = &g.tensors[op.inputs[0]];
            (t.size, cm.compress_secs(t.class, t.size))
        }
        OpKind::Decompress => {
            let t = &g.tensors[op.outputs[0]];
            (t.size, cm.decompress_secs(t.class, t.size))
        }
        _ => {
            let bytes: u64 = op.outputs.iter().map(|&t| g.tensors[t].size).sum();
            (bytes, m.op_secs(g, op.id))
        }
    }
}

/// Emit one [`OP_COST_EVENT`] instant per operator of `g` into the span
/// recorder (no-op while tracing is off). The seconds are the active
/// cost source's — so a traced proxy run harvests into a table that
/// reproduces the proxy, and a PJRT-measured run (which records real
/// wall-clock spans) harvests real kernels; either way
/// `trace → calibrate → --calib-table` is self-consistent, which is what
/// lets `roam audit` pin drift == 0 on an unchanged table.
pub fn emit_op_costs(
    g: &Graph,
    m: &crate::swap::cost::CostModel,
    cm: &crate::compress::cost::CompressModel,
) {
    if !span::enabled() {
        return;
    }
    for op in &g.ops {
        let (bytes, secs) = modeled_op_cost(g, op, m, cm);
        if !secs.is_finite() {
            continue; // codec-less Compress ops price at infinity
        }
        span::instant(
            OP_COST_EVENT,
            vec![
                ("kind", ArgVal::Str(kind_name(op.kind).to_string())),
                ("bytes", ArgVal::Num(bytes as f64)),
                ("secs", ArgVal::Num(secs)),
            ],
        );
    }
}

/// Fold drained span events into a table: every [`OP_COST_EVENT`]
/// instant carrying `kind`/`bytes`/`secs` args becomes one sample.
pub fn harvest_events(events: &[Event]) -> CostTable {
    let mut t = CostTable::default();
    for e in events {
        if e.phase != Phase::Instant || e.name != OP_COST_EVENT {
            continue;
        }
        let (mut kind, mut bytes, mut secs) = (None, None, None);
        for (k, v) in &e.args {
            match (*k, v) {
                ("kind", ArgVal::Str(s)) => kind = Some(s.as_str()),
                ("bytes", ArgVal::Num(n)) => bytes = Some(*n as u64),
                ("secs", ArgVal::Num(n)) => secs = Some(*n),
                _ => {}
            }
        }
        if let (Some(k), Some(b), Some(s)) = (kind, bytes, secs) {
            t.add_sample(k, b, s);
        }
    }
    t
}

/// Fold a saved `--trace-out` Chrome trace document into a table —
/// identical result to [`harvest_events`] on the events that produced it
/// (pinned by `tests/calib_props.rs`; the f64 JSON round-trip is exact).
pub fn harvest_chrome_trace(doc: &Json) -> Result<CostTable, String> {
    let events = doc
        .get("traceEvents")
        .and_then(|j| j.as_arr())
        .ok_or("trace missing top-level 'traceEvents'")?;
    let mut t = CostTable::default();
    for e in events {
        if e.get("ph").and_then(|j| j.as_str()) != Some("i")
            || e.get("name").and_then(|j| j.as_str()) != Some(OP_COST_EVENT)
        {
            continue;
        }
        let Some(args) = e.get("args") else { continue };
        let kind = args.get("kind").and_then(|j| j.as_str());
        let bytes = args.get("bytes").and_then(|j| j.as_u64());
        let secs = args.get("secs").and_then(|j| j.as_f64());
        if let (Some(k), Some(b), Some(s)) = (kind, bytes, secs) {
            t.add_sample(k, b, s);
        }
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    // The install/lookup global state is exercised only by
    // `tests/calib_props.rs` (its own process, serialized on a lock) so
    // these in-crate tests can never race the cost-model unit tests that
    // pin exact proxy arithmetic. Here: the pure pieces.

    #[test]
    fn byte_buckets() {
        assert_eq!(byte_bucket(0), 0);
        assert_eq!(byte_bucket(1), 0);
        assert_eq!(byte_bucket(2), 1);
        assert_eq!(byte_bucket(3), 2);
        assert_eq!(byte_bucket(4), 2);
        assert_eq!(byte_bucket(5), 3);
        assert_eq!(byte_bucket(1 << 20), 20);
        assert_eq!(byte_bucket((1 << 20) + 1), 21);
        assert_eq!(byte_bucket(u64::MAX), 64);
    }

    #[test]
    fn median_and_lookup() {
        let mut t = CostTable::default();
        t.add_sample("Conv", 100, 3.0);
        t.add_sample("Conv", 101, 1.0);
        t.add_sample("Conv", 102, 2.0);
        // 100..=102 share bucket 7; median of {1,2,3} = 2.
        assert_eq!(t.secs_for("Conv", 100), Some(2.0));
        assert_eq!(t.secs_for("Conv", 128), Some(2.0));
        assert_eq!(t.secs_for("Conv", 129), None); // bucket 8
        assert_eq!(t.secs_for("MatMul", 100), None);
        t.add_sample("Conv", 100, 10.0);
        assert_eq!(t.secs_for("Conv", 100), Some(2.5)); // even count
    }

    #[test]
    fn rejects_poisoned_samples() {
        let mut t = CostTable::default();
        t.add_sample("Conv", 8, f64::NAN);
        t.add_sample("Conv", 8, f64::INFINITY);
        t.add_sample("Conv", 8, -1.0);
        assert!(t.is_empty());
    }

    #[test]
    fn merge_is_commutative() {
        let mut a = CostTable::default();
        a.add_sample("Conv", 64, 2.0);
        a.add_sample("MatMul", 64, 5.0);
        let mut b = CostTable::default();
        b.add_sample("Conv", 64, 1.0);
        b.add_sample("Conv", 4096, 9.0);
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, ba);
        assert_eq!(ab.fingerprint(), ba.fingerprint());
        assert_eq!(ab.n_entries(), 3);
        assert_eq!(ab.n_samples(), 4);
    }

    #[test]
    fn json_round_trip_is_lossless() {
        let mut t = CostTable::default();
        t.add_sample("Conv", 1 << 20, 1.25e-3);
        t.add_sample("Conv", 1 << 20, 0.1 + 0.2); // non-terminating repr
        t.add_sample("SwapOut", 3, 7.0);
        let doc = t.to_json();
        let back = CostTable::from_json(&Json::parse(&doc.pretty()).unwrap()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.fingerprint(), t.fingerprint());
    }

    #[test]
    fn from_json_rejects_drift() {
        assert!(CostTable::from_json(&Json::obj(vec![(
            "schema",
            Json::Str("cost-table-v0".into())
        )]))
        .is_err());
        let bad = Json::obj(vec![
            ("schema", Json::Str(SCHEMA.into())),
            (
                "entries",
                Json::Arr(vec![Json::obj(vec![("kind", Json::Str("Conv".into()))])]),
            ),
        ]);
        assert!(CostTable::from_json(&bad).is_err());
    }

    #[test]
    fn kind_name_is_total_and_distinct() {
        let all = [
            OpKind::Conv,
            OpKind::MatMul,
            OpKind::BatchNorm,
            OpKind::LayerNorm,
            OpKind::Activation,
            OpKind::Softmax,
            OpKind::Pool,
            OpKind::Elementwise,
            OpKind::Reshape,
            OpKind::Reduce,
            OpKind::Embed,
            OpKind::Loss,
            OpKind::GradAcc,
            OpKind::OptimStep,
            OpKind::Input,
            OpKind::SwapOut,
            OpKind::SwapIn,
            OpKind::Compress,
            OpKind::Decompress,
            OpKind::Other,
        ];
        let names: std::collections::BTreeSet<_> = all.iter().map(|&k| kind_name(k)).collect();
        assert_eq!(names.len(), all.len());
    }
}

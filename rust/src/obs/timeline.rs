//! Per-plan memory-timeline profiling: where did the peak come from?
//!
//! Wraps the ground-truth simulator ([`crate::sched::sim`]) into an
//! operator-facing report: bytes live at every timestep, the argmax
//! timestep, and a per-tensor attribution of the peak — which tensors
//! hold bytes at the peak step, who produced them, and whether the
//! eviction substrate ([`crate::evict::is_evictable`]) could target them
//! (i.e. whether a recompute/swap rewrite would actually dent the peak).
//!
//! By the simulator's own pinned invariant (`live_at_matches_profile`),
//! the attribution **sums exactly** to the simulated peak bytes —
//! `tests/obs_props.rs` re-pins that end-to-end. Rendered as an ASCII
//! sparkline by `roam inspect`, exported as JSON with `--out`.

use crate::evict::is_evictable;
use crate::graph::{Graph, OpId, TensorId};
use crate::sched::sim::{live_at, profile};
use crate::sched::Schedule;
use crate::util::human_bytes;
use crate::util::json::Json;

/// One tensor holding bytes at the peak timestep.
#[derive(Clone, Debug)]
pub struct PeakHolder {
    pub tensor: TensorId,
    pub name: String,
    pub bytes: u64,
    /// Producing op (`None` for graph inputs).
    pub producer: Option<OpId>,
    pub producer_name: String,
    /// Could the eviction substrate free this tensor (recompute or swap
    /// rewrite candidate)? `false` marks structural residents the peak
    /// cannot shed without reordering.
    pub evictable: bool,
}

/// Memory timeline of a schedule on a graph.
#[derive(Clone, Debug)]
pub struct Timeline {
    /// Live dynamic bytes at every timestep.
    pub per_step: Vec<u64>,
    /// max(per_step) — the theoretical peak.
    pub peak: u64,
    /// First timestep attaining the peak.
    pub peak_step: usize,
    /// Constant resident set (weights + optimizer state).
    pub persistent: u64,
    /// Peak attribution: every dynamic tensor live at `peak_step`,
    /// largest first. Sizes sum exactly to `peak`.
    pub holders: Vec<PeakHolder>,
}

impl Timeline {
    /// Profile `sched` on `g` and attribute the peak.
    pub fn compute(g: &Graph, sched: &Schedule) -> Timeline {
        let prof = profile(g, sched);
        let mut holders: Vec<PeakHolder> = live_at(g, sched, prof.peak_step)
            .into_iter()
            .map(|tid| {
                let t = &g.tensors[tid];
                let producer_name = t
                    .producer
                    .map(|op| g.ops[op].name.clone())
                    .unwrap_or_default();
                PeakHolder {
                    tensor: tid,
                    name: t.name.clone(),
                    bytes: t.size,
                    producer: t.producer,
                    producer_name,
                    evictable: is_evictable(g, tid),
                }
            })
            .collect();
        holders.sort_by(|a, b| b.bytes.cmp(&a.bytes).then(a.tensor.cmp(&b.tensor)));
        Timeline {
            per_step: prof.per_step,
            peak: prof.peak,
            peak_step: prof.peak_step,
            persistent: prof.persistent,
            holders,
        }
    }

    /// Sum of the attributed holder bytes. Equals [`Timeline::peak`] by
    /// the simulator's liveness invariant (re-pinned in tests).
    pub fn attributed_bytes(&self) -> u64 {
        self.holders.iter().map(|h| h.bytes).sum()
    }

    /// Bytes an eviction-substrate rewrite could shed at the peak.
    pub fn evictable_bytes(&self) -> u64 {
        self.holders
            .iter()
            .filter(|h| h.evictable)
            .map(|h| h.bytes)
            .sum()
    }

    /// ASCII sparkline of the timeline, `width` columns wide (each column
    /// shows the max over its chunk of timesteps, on a 10-glyph ramp).
    pub fn sparkline(&self, width: usize) -> String {
        sparkline(&self.per_step, width)
    }

    /// JSON export (stable key order via the JSON substrate).
    pub fn to_json(&self) -> Json {
        let holders = self
            .holders
            .iter()
            .map(|h| {
                Json::obj(vec![
                    ("tensor", Json::Num(h.tensor as f64)),
                    ("name", Json::Str(h.name.clone())),
                    ("bytes", Json::Num(h.bytes as f64)),
                    (
                        "producer",
                        match h.producer {
                            Some(op) => Json::Num(op as f64),
                            None => Json::Null,
                        },
                    ),
                    ("producer_name", Json::Str(h.producer_name.clone())),
                    ("evictable", Json::Bool(h.evictable)),
                ])
            })
            .collect();
        Json::obj(vec![
            (
                "per_step",
                Json::Arr(self.per_step.iter().map(|&b| Json::Num(b as f64)).collect()),
            ),
            ("peak", Json::Num(self.peak as f64)),
            ("peak_step", Json::Num(self.peak_step as f64)),
            ("persistent", Json::Num(self.persistent as f64)),
            ("attributed_bytes", Json::Num(self.attributed_bytes() as f64)),
            ("evictable_bytes", Json::Num(self.evictable_bytes() as f64)),
            ("holders", Json::Arr(holders)),
        ])
    }

    /// Human report for `roam inspect`: sparkline + peak attribution
    /// table (top `top_k` holders).
    pub fn render(&self, width: usize, top_k: usize) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "memory timeline: {} steps, peak {} at step {} (persistent {})\n",
            self.per_step.len(),
            human_bytes(self.peak),
            self.peak_step,
            human_bytes(self.persistent),
        ));
        out.push_str(&format!("  [{}]\n", self.sparkline(width)));
        out.push_str(&format!(
            "peak attribution ({} tensors, {} evictable by recompute/swap):\n",
            self.holders.len(),
            human_bytes(self.evictable_bytes()),
        ));
        for h in self.holders.iter().take(top_k) {
            let producer = if h.producer_name.is_empty() {
                "<input>"
            } else {
                &h.producer_name
            };
            out.push_str(&format!(
                "  {:>10}  {}  (from {}{})\n",
                human_bytes(h.bytes),
                h.name,
                producer,
                if h.evictable { ", evictable" } else { "" },
            ));
        }
        if self.holders.len() > top_k {
            let rest: u64 = self.holders.iter().skip(top_k).map(|h| h.bytes).sum();
            out.push_str(&format!(
                "  {:>10}  … {} more tensors\n",
                human_bytes(rest),
                self.holders.len() - top_k,
            ));
        }
        out
    }
}

/// Glyph ramp for sparklines, lightest to densest.
const RAMP: &[u8] = b" .:-=+*#%@";

/// Downsample `per_step` to `width` columns (max over each chunk) and
/// map onto the glyph ramp, scaled so the peak hits the densest glyph.
pub fn sparkline(per_step: &[u64], width: usize) -> String {
    if per_step.is_empty() || width == 0 {
        return String::new();
    }
    let peak = per_step.iter().copied().max().unwrap_or(0);
    let cols = width.min(per_step.len());
    let mut out = String::with_capacity(cols);
    for c in 0..cols {
        // Chunk [lo, hi) of the timeline feeding column c.
        let lo = c * per_step.len() / cols;
        let hi = ((c + 1) * per_step.len() / cols).max(lo + 1);
        let m = per_step[lo..hi].iter().copied().max().unwrap_or(0);
        let idx = if peak == 0 {
            0
        } else {
            // Nonzero values never map to the blank glyph.
            (((m as u128) * (RAMP.len() as u128 - 1)).div_ceil(peak as u128)) as usize
        };
        out.push(RAMP[idx.min(RAMP.len() - 1)] as char);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, Phase, TensorClass};

    fn tiny() -> Graph {
        let mut g = Graph::new("tl");
        let x = g.add_input_tensor("x", 8, TensorClass::Input);
        let (_, a) = g.add_op(
            "a",
            OpKind::Other,
            Phase::Forward,
            &[x],
            &[("ta", 100, TensorClass::Activation)],
        );
        let (_, b) = g.add_op(
            "b",
            OpKind::Other,
            Phase::Forward,
            &[a[0]],
            &[("tb", 40, TensorClass::Activation)],
        );
        g.mark_output(b[0]);
        g
    }

    #[test]
    fn attribution_sums_to_peak() {
        let g = tiny();
        let s = Schedule::from_order(&[0, 1]);
        let tl = Timeline::compute(&g, &s);
        assert_eq!(tl.attributed_bytes(), tl.peak);
        assert_eq!(tl.per_step[tl.peak_step], tl.peak);
        // Largest holder first.
        assert!(tl.holders.windows(2).all(|w| w[0].bytes >= w[1].bytes));
    }

    #[test]
    fn json_roundtrips_and_is_consistent() {
        let g = tiny();
        let s = Schedule::from_order(&[0, 1]);
        let tl = Timeline::compute(&g, &s);
        let j = tl.to_json();
        assert_eq!(Json::parse(&j.pretty()).unwrap(), j);
        assert_eq!(j.get("peak").unwrap().as_u64(), Some(tl.peak));
        assert_eq!(
            j.get("attributed_bytes").unwrap().as_u64(),
            Some(tl.peak)
        );
        assert_eq!(
            j.get("holders").unwrap().as_arr().unwrap().len(),
            tl.holders.len()
        );
    }

    #[test]
    fn sparkline_shape() {
        assert_eq!(sparkline(&[], 10), "");
        assert_eq!(sparkline(&[0, 0], 2), "  ");
        let s = sparkline(&[1, 2, 4, 8], 4);
        assert_eq!(s.len(), 4);
        // Peak maps to the densest glyph; nonzero never blank.
        assert_eq!(s.as_bytes()[3], b'@');
        assert!(!s.contains(' '));
        // Wider than the data: clamps to one column per step.
        assert_eq!(sparkline(&[5], 80).len(), 1);
    }

    #[test]
    fn render_mentions_peak_and_holders() {
        let g = tiny();
        let s = Schedule::from_order(&[0, 1]);
        let tl = Timeline::compute(&g, &s);
        let r = tl.render(40, 10);
        assert!(r.contains("peak"));
        assert!(r.contains("ta"));
    }
}

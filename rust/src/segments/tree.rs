//! Algorithm 1: `ConstructSubgraphTree`.
//!
//! The tree has three levels (Fig 10): the root (whole DNN graph),
//! independent-subgraph (IG) nodes — our nested windows, formed from an
//! independent segment in the forward pass and the corresponding segment in
//! the backward pass — and dependent-subgraph (DG) nodes created by
//! splitting any IG whose op count exceeds the user's `node_limit`.
//!
//! Leaves are what the leaf solvers (branch-and-bound ordering / DSA
//! layout) actually receive; non-leaf nodes aggregate children per
//! eqs. (3) and (9).

use super::{boundaries, segments, windows, Segment, Window};
use crate::graph::{Graph, OpId, Reachability};

/// A node of the subgraph tree.
#[derive(Clone, Debug)]
pub enum NodeKind {
    Root,
    /// Independent subgraph = window (fwd segment + paired bwd segment).
    Ig(Window),
    /// Dependent subgraph: a `node_limit`-sized slice of one segment.
    Dg { window: usize, part: usize },
}

/// Tree node: ops it owns (for leaves) and child indices.
#[derive(Clone, Debug)]
pub struct Node {
    pub kind: NodeKind,
    pub ops: Vec<OpId>,
    pub children: Vec<usize>,
}

/// The subgraph tree plus the division metadata the planner consumes.
#[derive(Clone, Debug)]
pub struct SubgraphTree {
    pub nodes: Vec<Node>,
    /// Memory-insensitive boundary ops in precedence order.
    pub boundaries: Vec<OpId>,
    /// Independent segments (index space shared with `windows`).
    pub segments: Vec<Segment>,
    /// Window pairing of segments.
    pub windows: Vec<Window>,
    /// Ordering tasks: per segment, chunks of ≤ node_limit ops that the
    /// leaf scheduler optimises independently (DG split of Algorithm 1).
    pub order_tasks: Vec<OrderTask>,
}

/// One leaf ordering task: a slice of a segment.
#[derive(Clone, Debug)]
pub struct OrderTask {
    pub segment: usize,
    pub part: usize,
    pub ops: Vec<OpId>,
}

/// `node_limit` configuration (the paper's user parameter).
#[derive(Clone, Copy, Debug)]
pub struct TreeCfg {
    pub node_limit: usize,
}

impl Default for TreeCfg {
    fn default() -> Self {
        TreeCfg { node_limit: 64 }
    }
}

/// The boundary/segment division underlying the tree. Split out of
/// [`construct`] so the serving layer's per-segment fingerprints
/// ([`crate::serve::segment_signature`]) use the *same* division — a
/// "dirty segment" index means the same thing to the cache and to the
/// planner.
#[derive(Clone, Debug)]
pub struct Division {
    /// Memory-insensitive boundary ops in precedence order.
    pub boundaries: Vec<OpId>,
    /// Independent segments between consecutive boundaries; segment `i`
    /// closes at `boundaries[i]` (the last closes at graph end).
    pub segments: Vec<Segment>,
}

/// Compute the boundary/segment division of `g`.
pub fn division(g: &Graph, reach: &Reachability) -> Division {
    let bounds = boundaries(g, reach);
    let segs = segments(g, reach, &bounds);
    Division {
        boundaries: bounds,
        segments: segs,
    }
}

/// Construct the subgraph tree (Algorithm 1).
pub fn construct(g: &Graph, reach: &Reachability, cfg: &TreeCfg) -> SubgraphTree {
    let div = division(g, reach);
    let (bounds, segs) = (div.boundaries, div.segments);
    let wins = windows(segs.len());

    let mut nodes = vec![Node {
        kind: NodeKind::Root,
        ops: (0..g.n_ops()).collect(),
        children: Vec::new(),
    }];
    let mut order_tasks = Vec::new();

    for w in &wins {
        let mut ig_ops: Vec<OpId> = segs[w.fwd_seg].ops.clone();
        if w.bwd_seg != w.fwd_seg {
            ig_ops.extend_from_slice(&segs[w.bwd_seg].ops);
        }
        let ig_idx = nodes.len();
        nodes.push(Node {
            kind: NodeKind::Ig(*w),
            ops: ig_ops.clone(),
            children: Vec::new(),
        });
        nodes[0].children.push(ig_idx);

        // Split-down: each owned segment contributes ordering chunks of at
        // most node_limit ops (ASAP-ordered so chunks respect precedence
        // as much as the division allows).
        let mut seg_list = vec![w.fwd_seg];
        if w.bwd_seg != w.fwd_seg {
            seg_list.push(w.bwd_seg);
        }
        for seg_idx in seg_list {
            let mut ops = segs[seg_idx].ops.clone();
            ops.sort_by_key(|&v| (reach.asap(v), v));
            let chunks: Vec<Vec<OpId>> = if ops.is_empty() {
                Vec::new()
            } else {
                ops.chunks(cfg.node_limit).map(|c| c.to_vec()).collect()
            };
            let split = chunks.len() > 1;
            for (part, chunk) in chunks.into_iter().enumerate() {
                order_tasks.push(OrderTask {
                    segment: seg_idx,
                    part,
                    ops: chunk.clone(),
                });
                if split {
                    let dg_idx = nodes.len();
                    nodes.push(Node {
                        kind: NodeKind::Dg { window: w.k, part },
                        ops: chunk,
                        children: Vec::new(),
                    });
                    nodes[ig_idx].children.push(dg_idx);
                }
            }
        }
    }

    SubgraphTree {
        nodes,
        boundaries: bounds,
        segments: segs,
        windows: wins,
        order_tasks,
    }
}

impl SubgraphTree {
    /// Number of leaf nodes (IGs without children + DGs).
    pub fn n_leaves(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.children.is_empty() && !matches!(n.kind, NodeKind::Root))
            .count()
    }

    /// Depth of the tree (1 = root only).
    pub fn depth(&self) -> usize {
        if self.nodes.len() == 1 {
            return 1;
        }
        if self
            .nodes
            .iter()
            .any(|n| matches!(n.kind, NodeKind::Dg { .. }))
        {
            3
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::random::{random_training_graph, RandomGraphCfg};
    use crate::util::quick::forall;
    use crate::util::Pcg64;

    #[test]
    fn tree_covers_all_ops() {
        forall("tree order tasks + boundaries cover ops", 25, |rng| {
            let fwd_ops = rng.usize_in(3, 20);
            let g = random_training_graph(rng, &RandomGraphCfg {
                fwd_ops,
                ..Default::default()
            });
            let reach = Reachability::compute(&g);
            let tree = construct(&g, &reach, &TreeCfg { node_limit: 8 });
            let mut seen = vec![false; g.n_ops()];
            for &b in &tree.boundaries {
                seen[b] = true;
            }
            for t in &tree.order_tasks {
                for &v in &t.ops {
                    if seen[v] {
                        return Err(format!("op {v} assigned twice"));
                    }
                    seen[v] = true;
                }
            }
            if seen.iter().all(|&s| s) {
                Ok(())
            } else {
                Err("some op unassigned".into())
            }
        });
    }

    #[test]
    fn node_limit_caps_task_size() {
        let mut rng = Pcg64::new(17);
        let g = random_training_graph(&mut rng, &RandomGraphCfg {
            fwd_ops: 30,
            ..Default::default()
        });
        let reach = Reachability::compute(&g);
        for limit in [4usize, 16, 64] {
            let tree = construct(&g, &reach, &TreeCfg { node_limit: limit });
            assert!(tree.order_tasks.iter().all(|t| t.ops.len() <= limit));
        }
    }

    #[test]
    fn three_level_structure_when_split() {
        let mut rng = Pcg64::new(23);
        let g = random_training_graph(&mut rng, &RandomGraphCfg {
            fwd_ops: 25,
            skip_p: 0.8, // big segments
            ..Default::default()
        });
        let reach = Reachability::compute(&g);
        let small = construct(&g, &reach, &TreeCfg { node_limit: 4 });
        assert_eq!(small.depth(), 3, "tiny node_limit must force DG level");
        let big = construct(&g, &reach, &TreeCfg { node_limit: 10_000 });
        assert!(big.depth() <= 2);
        assert!(small.n_leaves() >= big.n_leaves());
    }
}

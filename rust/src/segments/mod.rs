//! Graph division: memory-insensitive operators, independent segments and
//! the subgraph tree (§IV-A, §IV-C).
//!
//! * A **memory-insensitive operator** has the same scheduling timestep in
//!   every topological order — formally, it is comparable with every other
//!   operator (`|pred*| + |succ*| = n − 1`). These ops are the graph's
//!   natural cut points.
//! * An **independent segment** is the set of operators strictly between
//!   two consecutive memory-insensitive boundaries; its internal order is
//!   the only scheduling freedom (eq. 1/2), so leaves can be optimised
//!   independently and concatenated (eq. 3).
//! * For layout, forward segments pair with their corresponding backward
//!   segments into nested **windows** (independent subgraphs, §IV-B/C):
//!   window `k` spans boundary `k` to boundary `m−k` in execution time.
//!   Every tensor is assigned to the innermost window containing its
//!   lifetime; tensors spanning the next-inner window are the "long-lived
//!   activations" stacked at the bottom of each sub-layout (Fig 5).
//!
//! [`tree`] implements Algorithm 1: independent-subgraph generation plus
//! `node_limit`-driven split-down into dependent subgraphs.

pub mod tree;

use crate::graph::{Graph, OpId, Reachability};

/// Memory-insensitive operators in precedence (= ASAP) order.
pub fn boundaries(g: &Graph, reach: &Reachability) -> Vec<OpId> {
    let mut b: Vec<OpId> = (0..g.n_ops())
        .filter(|&v| reach.is_memory_insensitive(v))
        .collect();
    b.sort_by_key(|&v| reach.asap(v));
    b
}

/// Memory-insensitive operators of the fwd/loss/bwd core, *ignoring the
/// weight-update branches* (§IV-A): update branches are mutually
/// incomparable and would otherwise destroy every backward boundary —
/// "we can find memory-insensitive operators in the backward pass that
/// correspond to memory-insensitive operators in the forward pass". The
/// weight-update scheduler then anchors each update branch between two of
/// these candidate boundaries, restoring their insensitivity in the
/// augmented graph.
///
/// Update ops are pure sinks (their outputs are only graph outputs), so
/// comparability among core ops in the full graph equals comparability in
/// the core subgraph — we just mask the counts.
pub fn boundaries_core(g: &Graph, reach: &Reachability) -> Vec<OpId> {
    use crate::util::BitSet;
    let n = g.n_ops();
    let mut core_mask = BitSet::new(n);
    let mut n_core = 0usize;
    for op in &g.ops {
        if op.phase != crate::graph::Phase::Update {
            core_mask.set(op.id);
            n_core += 1;
        }
    }
    if n_core == 0 {
        return Vec::new();
    }
    let mut b: Vec<OpId> = (0..n)
        .filter(|&v| {
            core_mask.get(v)
                && reach.above[v].count_and(&core_mask) + reach.below[v].count_and(&core_mask)
                    == n_core - 1
        })
        .collect();
    b.sort_by_key(|&v| reach.asap(v));
    b
}

/// An independent segment: ops strictly between two boundaries.
#[derive(Clone, Debug)]
pub struct Segment {
    /// Boundary op that opens the segment (`None` = graph start).
    pub open: Option<OpId>,
    /// Boundary op that closes the segment (`None` = graph end).
    pub close: Option<OpId>,
    /// The schedulable ops inside (excludes the boundaries).
    pub ops: Vec<OpId>,
}

/// Partition all non-boundary ops into independent segments.
///
/// Segment membership of op `v`: the last boundary preceding `v`. Because
/// boundaries are comparable with every op, this is well-defined; ops
/// before the first boundary form segment 0 with `open = None`.
pub fn segments(g: &Graph, reach: &Reachability, bounds: &[OpId]) -> Vec<Segment> {
    let mut segs: Vec<Segment> = Vec::with_capacity(bounds.len() + 1);
    for i in 0..=bounds.len() {
        segs.push(Segment {
            open: if i == 0 { None } else { Some(bounds[i - 1]) },
            close: bounds.get(i).copied(),
            ops: Vec::new(),
        });
    }
    let is_boundary: std::collections::HashSet<OpId> = bounds.iter().copied().collect();
    for v in 0..g.n_ops() {
        if is_boundary.contains(&v) {
            continue;
        }
        // Binary search over boundaries: the last one that precedes v.
        // Boundaries are sorted by ASAP and mutually comparable, so
        // "b precedes v" is monotone along the list.
        let mut lo = 0usize; // segs index
        let mut hi = bounds.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if reach.precedes(bounds[mid], v) {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        segs[lo].ops.push(v);
    }
    segs
}

/// A nested layout window (independent subgraph): boundary indices
/// `[lo_b, hi_b]` into the boundary list; the window spans execution time
/// from boundary `lo_b` to boundary `hi_b` and owns the forward segment
/// after `lo_b` plus the backward segment before `hi_b`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Window {
    pub k: usize,
    /// Segment index of the forward part (into the `segments` vec).
    pub fwd_seg: usize,
    /// Segment index of the backward part.
    pub bwd_seg: usize,
}

/// Build the nested window pairing: window k owns segments k and m−k.
/// With `m+1` segments there are `ceil((m+1)/2)` windows; the innermost
/// may own a single segment (when the count is odd).
pub fn windows(n_segments: usize) -> Vec<Window> {
    let mut out = Vec::new();
    let mut lo = 0usize;
    let mut hi = n_segments.saturating_sub(1);
    let mut k = 0usize;
    while lo <= hi && n_segments > 0 {
        out.push(Window {
            k,
            fwd_seg: lo,
            bwd_seg: hi,
        });
        if lo == hi {
            break;
        }
        lo += 1;
        hi -= 1;
        k += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::random::{random_training_graph, RandomGraphCfg};
    use crate::models::{self, BuildCfg, ModelKind};
    use crate::util::quick::forall;

    #[test]
    fn chain_is_all_boundaries() {
        use crate::graph::{Graph, OpKind, Phase, TensorClass};
        let mut g = Graph::new("chain");
        let mut prev = g.add_input_tensor("x", 1, TensorClass::Input);
        for i in 0..6 {
            let (_, t) = g.add_op(format!("op{i}"), OpKind::Other, Phase::Forward,
                &[prev], &[("t", 1, TensorClass::Activation)]);
            prev = t[0];
        }
        let r = Reachability::compute(&g);
        let b = boundaries(&g, &r);
        assert_eq!(b.len(), 6);
        let segs = segments(&g, &r, &b);
        assert!(segs.iter().all(|s| s.ops.is_empty()));
    }

    #[test]
    fn segments_partition_ops() {
        forall("segments partition non-boundary ops", 30, |rng| {
            let fwd_ops = rng.usize_in(3, 15);
            let g = random_training_graph(rng, &RandomGraphCfg {
                fwd_ops,
                ..Default::default()
            });
            let r = Reachability::compute(&g);
            let b = boundaries(&g, &r);
            let segs = segments(&g, &r, &b);
            let total: usize = segs.iter().map(|s| s.ops.len()).sum();
            if total + b.len() != g.n_ops() {
                return Err(format!(
                    "{} seg ops + {} boundaries != {} ops",
                    total,
                    b.len(),
                    g.n_ops()
                ));
            }
            // Each segment op must be after open and before close.
            for s in &segs {
                for &v in &s.ops {
                    if let Some(o) = s.open {
                        if !r.precedes(o, v) {
                            return Err(format!("op {v} not after open {o}"));
                        }
                    }
                    if let Some(c) = s.close {
                        if !r.precedes(v, c) {
                            return Err(format!("op {v} not before close {c}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn models_have_many_boundaries() {
        let g = models::build(ModelKind::Alexnet, &BuildCfg::default());
        let r = Reachability::compute(&g);
        let b = boundaries(&g, &r);
        // The fwd trunk of AlexNet is a chain: many memory-insensitive ops.
        assert!(b.len() > 5, "only {} boundaries", b.len());
        let segs = segments(&g, &r, &b);
        assert_eq!(
            segs.iter().map(|s| s.ops.len()).sum::<usize>() + b.len(),
            g.n_ops()
        );
    }

    #[test]
    fn window_pairing_nests() {
        let w = windows(5);
        assert_eq!(w.len(), 3);
        assert_eq!((w[0].fwd_seg, w[0].bwd_seg), (0, 4));
        assert_eq!((w[1].fwd_seg, w[1].bwd_seg), (1, 3));
        assert_eq!((w[2].fwd_seg, w[2].bwd_seg), (2, 2));
        assert_eq!(windows(1).len(), 1);
        assert_eq!(windows(0).len(), 0);
    }
}

//! # ROAM — memory-efficient DNN training via operator ordering + memory layout
//!
//! Reproduction of *ROAM: memory-efficient large DNN training via optimized
//! operator ordering and memory layout* (Shu et al., 2023).
//!
//! ROAM operates on the computation-graph level. Given a training graph
//! (operators + tensors with byte sizes), it derives an **execution plan**:
//!
//! * an operator **execution order** minimising the *theoretical peak memory*
//!   ([`sched`]), and
//! * a static **memory layout** (byte offset per tensor) minimising the
//!   *actual peak* / fragmentation ([`layout`]).
//!
//! Scalability to 10k+-operator training graphs comes from divide and
//! conquer: split at *memory-insensitive operators* into *independent
//! segments*, pair forward/backward segments into subgraphs, organise them
//! in a **subgraph tree** ([`segments`]), solve each leaf exactly with
//! branch-and-bound / ILP ([`ilp`]), and concatenate the sub-plans
//! ([`planner`]).
//!
//! On top of the planner sit the high-level memory techniques, all
//! sharing one eviction substrate ([`evict`]) and one budgeted driver
//! ([`hybrid`]):
//!
//! * [`recompute`] — budgeted rematerialization: evict activations,
//!   clone their producers into the backward pass
//!   ([`recompute::roam_plan_budgeted`]);
//! * [`swap`] — bandwidth-aware CPU/NVMe offloading: `SwapOut`/`SwapIn`
//!   pairs priced by a modeled PCIe link, with transfer time hidden
//!   under the compute window the schedule provides;
//! * [`compress`] — in-place tensor compression: `Compress`/`Decompress`
//!   pairs shrinking resident activations with a pluggable per-class
//!   codec table, priced in pure codec seconds (no link, no re-execution);
//! * [`hybrid::roam_plan_hybrid`] — per-tensor technique assignment by
//!   cheapest overhead across all three, re-running the full ROAM
//!   order+layout pipeline on every augmented graph — the paper's
//!   "reduce overheads from high-level techniques" claim, made
//!   end-to-end.
//!
//! Around the planner sits a **serving layer** ([`serve`]): a
//! content-addressed plan cache keyed by an isomorphism-invariant graph
//! fingerprint, a batched async-style planning service with single-flight
//! dedupe and per-request deadlines, and warm-started re-planning that
//! replays cached plans as search incumbents (`roam serve` /
//! `roam batch` on the CLI).
//!
//! The crate additionally ships the substrates a reproduction needs:
//! model-graph builders for the paper's eight evaluation models
//! ([`models`]), the PyTorch / LESCEA / LLFB / MODeL baselines, and an HLO
//! text parser so the planner can run on real JAX-lowered graphs
//! ([`hlo`]). Behind the off-by-default `pjrt` feature (which needs the
//! `xla` crate and its native toolchain — see `Cargo.toml`) live a PJRT
//! runtime (`runtime`) and a training coordinator (`coordinator`) that
//! drive the end-to-end example; the default build has **zero**
//! third-party dependencies.
//!
//! ## Quickstart
//!
//! Every planning mode — plain, warm-seeded, overlap-aware, budgeted —
//! goes through one builder, [`planner::PlanRequest`] (the historical
//! free functions remain as one-line delegations to it):
//!
//! ```no_run
//! use roam::models::{self, ModelKind, BuildCfg};
//! use roam::planner::{PlanRequest, RoamCfg};
//! use roam::hybrid::{BudgetSpec, HybridCfg};
//!
//! let g = models::build(ModelKind::Bert, &BuildCfg { batch: 1, ..Default::default() });
//! let plan = PlanRequest::new(&g).cfg(RoamCfg::default()).run().into_plan();
//! println!("theoretical peak = {} actual peak = {} frag = {:.2}%",
//!          plan.theoretical_peak, plan.actual_peak, plan.frag_pct());
//!
//! // Same model under a hard budget of 60% of the unbudgeted total:
//! let b = PlanRequest::new(&g)
//!     .hybrid_cfg(HybridCfg::default())
//!     .budget(BudgetSpec::Fraction(0.6))
//!     .run()
//!     .into_hybrid();
//! println!("budgeted total = {} (met: {}, +{} recompute ops)",
//!          b.total(), b.met, b.recompute_ops);
//! ```

pub mod benchkit;
pub mod compress;
#[cfg(feature = "pjrt")]
pub mod coordinator;
pub mod evict;
pub mod faults;
pub mod graph;
pub mod hlo;
pub mod hybrid;
pub mod ilp;
pub mod layout;
pub mod models;
pub mod obs;
pub mod planner;
pub mod recompute;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod sched;
pub mod segments;
pub mod serve;
pub mod swap;
pub mod util;

pub use graph::Graph;

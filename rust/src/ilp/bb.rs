//! Branch-and-bound MILP driver over the simplex LP relaxation.
//!
//! Depth-first search branching on the most-fractional integer variable,
//! with incumbent pruning and a wall-clock deadline — mirroring how the
//! paper runs its ILP solver "with a time limit of 3600 s" (§V-A) and
//! takes the incumbent when time runs out.

use super::model::{Cmp, LinExpr, Model};
use super::simplex::{solve_lp, LpStatus};
use crate::util::timer::Deadline;

/// MILP outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MilpStatus {
    /// Search space exhausted: incumbent is optimal.
    Optimal,
    /// Deadline/node budget hit with a feasible incumbent.
    Feasible,
    /// Proven infeasible.
    Infeasible,
    /// Deadline hit before any incumbent was found.
    Unknown,
}

/// MILP configuration.
#[derive(Clone, Debug)]
pub struct MilpCfg {
    pub deadline: Deadline,
    pub max_nodes: u64,
    /// Absolute objective tolerance for pruning.
    pub gap_tol: f64,
}

impl Default for MilpCfg {
    fn default() -> Self {
        MilpCfg {
            deadline: Deadline::unlimited(),
            max_nodes: 100_000,
            gap_tol: 1e-6,
        }
    }
}

/// MILP result.
#[derive(Clone, Debug)]
pub struct MilpResult {
    pub status: MilpStatus,
    pub x: Vec<f64>,
    pub objective: f64,
    pub nodes: u64,
}

const INT_TOL: f64 = 1e-6;

/// Solve `m` by branch-and-bound. An optional warm-start feasible solution
/// seeds the incumbent (the planner passes its heuristic solution).
pub fn solve_milp(m: &Model, cfg: &MilpCfg, warm: Option<&[f64]>) -> MilpResult {
    let mut best: Option<(f64, Vec<f64>)> = None;
    if let Some(w) = warm {
        if m.feasible(w, 1e-6) {
            best = Some((m.objective.eval(w), w.to_vec()));
        }
    }
    let mut nodes = 0u64;
    let mut exhausted = true;
    // Stack of bound overrides: (var, lo, hi) lists per node.
    let mut stack: Vec<Vec<(usize, f64, f64)>> = vec![Vec::new()];

    while let Some(bounds) = stack.pop() {
        nodes += 1;
        if cfg.deadline.expired() || nodes > cfg.max_nodes {
            exhausted = false;
            break;
        }
        // Apply bounds to a scratch model.
        let mut node = m.clone();
        let mut bad = false;
        for &(v, lo, hi) in &bounds {
            node.vars[v].lo = node.vars[v].lo.max(lo);
            node.vars[v].hi = node.vars[v].hi.min(hi);
            if node.vars[v].lo > node.vars[v].hi + 1e-12 {
                bad = true;
            }
        }
        if bad {
            continue;
        }
        let rel = solve_lp(&node);
        match rel.status {
            LpStatus::Infeasible => continue,
            LpStatus::Unbounded | LpStatus::IterLimit => {
                // Numerical trouble: treat as unexplorable (conservative).
                exhausted = false;
                continue;
            }
            LpStatus::Optimal => {}
        }
        if let Some((b, _)) = &best {
            if rel.objective >= *b - cfg.gap_tol {
                continue; // bound prune
            }
        }
        // Find most fractional integer variable.
        let frac = m
            .vars
            .iter()
            .enumerate()
            .filter(|(_, v)| v.integer)
            .map(|(i, _)| (i, (rel.x[i] - rel.x[i].round()).abs()))
            .filter(|&(_, f)| f > INT_TOL)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        match frac {
            None => {
                // Integral: new incumbent.
                let mut x = rel.x.clone();
                for (i, v) in m.vars.iter().enumerate() {
                    if v.integer {
                        x[i] = x[i].round();
                    }
                }
                let obj = m.objective.eval(&x);
                if best.as_ref().map(|(b, _)| obj < *b).unwrap_or(true) {
                    best = Some((obj, x));
                }
            }
            Some((v, _)) => {
                let f = rel.x[v].floor();
                // Explore the side closer to the relaxation first
                // (pushed last = popped first).
                let mut down = bounds.clone();
                down.push((v, f64::NEG_INFINITY, f));
                let mut up = bounds;
                up.push((v, f + 1.0, f64::INFINITY));
                if rel.x[v] - f > 0.5 {
                    stack.push(down);
                    stack.push(up);
                } else {
                    stack.push(up);
                    stack.push(down);
                }
            }
        }
    }

    match best {
        Some((obj, x)) => MilpResult {
            status: if exhausted {
                MilpStatus::Optimal
            } else {
                MilpStatus::Feasible
            },
            x,
            objective: obj,
            nodes,
        },
        None => MilpResult {
            status: if exhausted {
                MilpStatus::Infeasible
            } else {
                MilpStatus::Unknown
            },
            x: Vec::new(),
            objective: f64::NAN,
            nodes,
        },
    }
}

/// Convenience: add the constraint `a + b ≤ 1` (mutual exclusion).
pub fn at_most_one(m: &mut Model, a: usize, b: usize) {
    m.constrain(LinExpr::new().term(a, 1.0).term(b, 1.0), Cmp::Le, 1.0);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::{Cmp, LinExpr, Model};

    #[test]
    fn knapsack() {
        // max 10a + 6b + 4c (min negative) s.t. a+b+c <= 2 (binary).
        let mut m = Model::new();
        let a = m.add_bin("a");
        let b = m.add_bin("b");
        let c = m.add_bin("c");
        m.constrain(
            LinExpr::new().term(a, 1.0).term(b, 1.0).term(c, 1.0),
            Cmp::Le,
            2.0,
        );
        m.minimize(LinExpr::new().term(a, -10.0).term(b, -6.0).term(c, -4.0));
        let r = solve_milp(&m, &MilpCfg::default(), None);
        assert_eq!(r.status, MilpStatus::Optimal);
        assert!((r.objective - (-16.0)).abs() < 1e-6);
        assert_eq!(r.x[a].round() as i64, 1);
        assert_eq!(r.x[b].round() as i64, 1);
        assert_eq!(r.x[c].round() as i64, 0);
    }

    #[test]
    fn integer_rounding_matters() {
        // min -x s.t. 2x <= 3, x integer in [0, 5] → x = 1 (LP gives 1.5).
        let mut m = Model::new();
        let x = m.add_int("x", 0.0, 5.0);
        m.constrain(LinExpr::new().term(x, 2.0), Cmp::Le, 3.0);
        m.minimize(LinExpr::new().term(x, -1.0));
        let r = solve_milp(&m, &MilpCfg::default(), None);
        assert_eq!(r.status, MilpStatus::Optimal);
        assert_eq!(r.x[x].round() as i64, 1);
    }

    #[test]
    fn infeasible_milp() {
        let mut m = Model::new();
        let x = m.add_bin("x");
        m.constrain(LinExpr::var(x), Cmp::Ge, 2.0);
        m.minimize(LinExpr::var(x));
        let r = solve_milp(&m, &MilpCfg::default(), None);
        assert_eq!(r.status, MilpStatus::Infeasible);
    }

    #[test]
    fn warm_start_used_when_budget_zero() {
        let mut m = Model::new();
        let x = m.add_bin("x");
        m.minimize(LinExpr::var(x));
        let warm = vec![1.0];
        let r = solve_milp(
            &m,
            &MilpCfg {
                max_nodes: 0,
                ..Default::default()
            },
            Some(&warm),
        );
        assert_eq!(r.status, MilpStatus::Feasible);
        assert_eq!(r.x, warm);
    }

    #[test]
    fn big_m_disjunction() {
        // Two unit tasks must not overlap on a resource:
        // o1, o2 in [0, 10], either o1 + 1 <= o2 or o2 + 1 <= o1.
        // min o1 + o2 → {0, 1}.
        let mut m = Model::new();
        let o1 = m.add_var("o1", 0.0, 10.0);
        let o2 = m.add_var("o2", 0.0, 10.0);
        let z = m.add_bin("z"); // z=1 ⇒ o1 below o2
        let big = 100.0;
        // o1 + 1 - o2 <= M(1-z)
        m.constrain(
            LinExpr::new().term(o1, 1.0).term(o2, -1.0).term(z, big),
            Cmp::Le,
            big - 1.0,
        );
        // o2 + 1 - o1 <= Mz
        m.constrain(
            LinExpr::new().term(o2, 1.0).term(o1, -1.0).term(z, -big),
            Cmp::Le,
            -1.0,
        );
        m.minimize(LinExpr::new().term(o1, 1.0).term(o2, 1.0));
        let r = solve_milp(&m, &MilpCfg::default(), None);
        assert_eq!(r.status, MilpStatus::Optimal);
        assert!((r.objective - 1.0).abs() < 1e-6, "obj {}", r.objective);
        assert!((r.x[o1] - r.x[o2]).abs() >= 1.0 - 1e-6);
    }
}

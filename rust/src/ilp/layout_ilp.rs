//! The DSA (memory-layout) ILP (§IV-D): offset variables plus pairwise
//! above/below binaries with big-M non-overlap constraints.
//!
//! "The most critical constraint ... is to ensure that tensors with
//! overlapping lifetimes can not have overlapping address spaces, and the
//! target is to minimize the size of the required memory space."

use super::bb::{solve_milp, MilpCfg};
use super::model::{Cmp, LinExpr, Model};
use crate::layout::{Item, Layout};

/// Variable/constraint counts of the layout formulation (used by benches
/// to demonstrate the whole-graph blow-up without solving).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LayoutFormulationSize {
    pub vars: u64,
    pub int_vars: u64,
    pub constraints: u64,
}

/// Count overlapping-lifetime pairs and derive formulation size.
pub fn formulation_size(items: &[Item]) -> LayoutFormulationSize {
    let mut pairs = 0u64;
    for (i, a) in items.iter().enumerate() {
        for b in items.iter().skip(i + 1) {
            if a.life.overlaps(&b.life) {
                pairs += 1;
            }
        }
    }
    LayoutFormulationSize {
        vars: items.len() as u64 + pairs + 1,
        int_vars: pairs,
        constraints: 2 * pairs + items.len() as u64,
    }
}

/// Result of the layout ILP.
#[derive(Clone, Debug)]
pub struct LayoutIlpResult {
    pub layout: Layout,
    pub arena: u64,
    pub status: super::bb::MilpStatus,
    pub nodes: u64,
}

/// Solve the layout ILP for (small) item sets. `warm` optionally seeds the
/// incumbent with a heuristic layout (e.g. LLFB).
pub fn solve(items: &[Item], cfg: &MilpCfg, warm: Option<&Layout>) -> LayoutIlpResult {
    let mut m = Model::new();
    let big: f64 = items.iter().map(|i| i.size as f64).sum::<f64>().max(1.0);
    let offs: Vec<_> = items
        .iter()
        .map(|it| m.add_var(format!("o_{}", it.id), 0.0, big))
        .collect();
    let arena = m.add_var("arena", 0.0, big);
    for (i, it) in items.iter().enumerate() {
        m.constrain(
            LinExpr::new().term(offs[i], 1.0).term(arena, -1.0),
            Cmp::Le,
            -(it.size as f64),
        );
    }
    let mut zvars = Vec::new();
    for i in 0..items.len() {
        for j in (i + 1)..items.len() {
            if !items[i].life.overlaps(&items[j].life) {
                continue;
            }
            let z = m.add_bin(format!("z_{}_{}", items[i].id, items[j].id));
            zvars.push((i, j, z));
            // z = 1 ⇒ i fully below j: o_i + s_i ≤ o_j.
            m.constrain(
                LinExpr::new()
                    .term(offs[i], 1.0)
                    .term(offs[j], -1.0)
                    .term(z, big),
                Cmp::Le,
                big - items[i].size as f64,
            );
            // z = 0 ⇒ j below i: o_j + s_j ≤ o_i.
            m.constrain(
                LinExpr::new()
                    .term(offs[j], 1.0)
                    .term(offs[i], -1.0)
                    .term(z, -big),
                Cmp::Le,
                -(items[j].size as f64),
            );
        }
    }
    m.minimize(LinExpr::var(arena));

    // Warm start: derive variable assignment from a heuristic layout.
    let warm_x = warm.map(|l| {
        let mut x = vec![0.0; m.n_vars()];
        for (i, it) in items.iter().enumerate() {
            x[offs[i]] = l.offset_of(it.id) as f64;
        }
        x[arena] = l.arena_size(items) as f64;
        for &(i, j, z) in &zvars {
            let oi = l.offset_of(items[i].id);
            let oj = l.offset_of(items[j].id);
            x[z] = if oi + items[i].size <= oj { 1.0 } else { 0.0 };
        }
        x
    });

    let r = solve_milp(&m, cfg, warm_x.as_deref());
    let layout = if r.x.is_empty() {
        Layout::default()
    } else {
        Layout {
            offsets: items
                .iter()
                .enumerate()
                .map(|(i, it)| (it.id, r.x[offs[i]].round().max(0.0) as u64))
                .collect(),
        }
    };
    let arena_v = layout.arena_size(items);
    LayoutIlpResult {
        layout,
        arena: arena_v,
        status: r.status,
        nodes: r.nodes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Lifetime;
    use crate::ilp::bb::MilpStatus;
    use crate::layout::dsa::{min_arena_layout, DsaCfg};
    use crate::layout::llfb::llfb;
    use crate::layout::sim::{conflicts, lower_bound};
    use crate::util::quick::forall;

    fn it(id: usize, birth: usize, death: usize, size: u64) -> Item {
        Item {
            id,
            life: Lifetime { birth, death },
            size,
        }
    }

    #[test]
    fn fig3_optimal() {
        let items = [it(0, 0, 1, 16), it(1, 0, 3, 12), it(2, 2, 3, 20)];
        let r = solve(&items, &MilpCfg::default(), None);
        assert_eq!(r.status, MilpStatus::Optimal);
        assert!(conflicts(&items, &r.layout).is_empty());
        assert_eq!(r.arena, 32);
    }

    #[test]
    fn agrees_with_dsa_bnb_on_random_instances() {
        forall("layout ILP == DSA search", 12, |rng| {
            let n = rng.usize_in(2, 7);
            let items: Vec<Item> = (0..n)
                .map(|id| {
                    let b = rng.usize_in(0, 6);
                    it(id, b, b + rng.usize_in(0, 4), 1 + rng.gen_range(64))
                })
                .collect();
            let ilp = solve(&items, &MilpCfg::default(), Some(&llfb(&items)));
            if ilp.status != MilpStatus::Optimal {
                return Ok(()); // budget edge; other tests cover validity
            }
            if !conflicts(&items, &ilp.layout).is_empty() {
                return Err("ILP layout conflicts".into());
            }
            let bnb = min_arena_layout(&items, &DsaCfg::default());
            // The ILP is exact: the search must never beat it, and when the
            // search reaches the LB they agree.
            if bnb.arena < ilp.arena {
                return Err(format!("bnb {} < ilp {}", bnb.arena, ilp.arena));
            }
            if bnb.proved_optimal && bnb.arena != ilp.arena {
                return Err(format!("both optimal yet differ: {} vs {}", bnb.arena, ilp.arena));
            }
            let _ = lower_bound(&items);
            Ok(())
        });
    }

    #[test]
    fn formulation_size_counts_pairs() {
        let items = [it(0, 0, 5, 8), it(1, 2, 6, 8), it(2, 7, 9, 8)];
        let f = formulation_size(&items);
        assert_eq!(f.int_vars, 1); // only (0,1) overlap
        assert_eq!(f.vars, 3 + 1 + 1);
        assert_eq!(f.constraints, 2 + 3);
    }
}

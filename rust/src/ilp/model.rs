//! LP / MILP model representation.

/// Variable handle.
pub type VarId = usize;

/// Comparison operator of a constraint row.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Cmp {
    Le,
    Ge,
    Eq,
}

/// Sparse linear expression: Σ coef·var.
#[derive(Clone, Debug, Default)]
pub struct LinExpr {
    pub terms: Vec<(VarId, f64)>,
}

impl LinExpr {
    pub fn new() -> Self {
        LinExpr { terms: Vec::new() }
    }

    pub fn term(mut self, v: VarId, c: f64) -> Self {
        self.terms.push((v, c));
        self
    }

    /// Single-variable expression.
    pub fn var(v: VarId) -> Self {
        LinExpr {
            terms: vec![(v, 1.0)],
        }
    }

    pub fn add(&mut self, v: VarId, c: f64) {
        self.terms.push((v, c));
    }

    /// Evaluate under an assignment.
    pub fn eval(&self, x: &[f64]) -> f64 {
        self.terms.iter().map(|&(v, c)| c * x[v]).sum()
    }
}

/// A variable's metadata.
#[derive(Clone, Debug)]
pub struct Var {
    pub name: String,
    pub lo: f64,
    pub hi: f64,
    pub integer: bool,
}

/// A constraint row `expr cmp rhs`.
#[derive(Clone, Debug)]
pub struct Constraint {
    pub expr: LinExpr,
    pub cmp: Cmp,
    pub rhs: f64,
}

/// A minimisation MILP.
#[derive(Clone, Debug, Default)]
pub struct Model {
    pub vars: Vec<Var>,
    pub constraints: Vec<Constraint>,
    pub objective: LinExpr,
}

impl Model {
    pub fn new() -> Self {
        Model::default()
    }

    /// Continuous variable in `[lo, hi]`.
    pub fn add_var(&mut self, name: impl Into<String>, lo: f64, hi: f64) -> VarId {
        self.vars.push(Var {
            name: name.into(),
            lo,
            hi,
            integer: false,
        });
        self.vars.len() - 1
    }

    /// Binary 0/1 variable.
    pub fn add_bin(&mut self, name: impl Into<String>) -> VarId {
        self.vars.push(Var {
            name: name.into(),
            lo: 0.0,
            hi: 1.0,
            integer: true,
        });
        self.vars.len() - 1
    }

    /// Integer variable in `[lo, hi]`.
    pub fn add_int(&mut self, name: impl Into<String>, lo: f64, hi: f64) -> VarId {
        self.vars.push(Var {
            name: name.into(),
            lo,
            hi,
            integer: true,
        });
        self.vars.len() - 1
    }

    pub fn constrain(&mut self, expr: LinExpr, cmp: Cmp, rhs: f64) {
        self.constraints.push(Constraint { expr, cmp, rhs });
    }

    pub fn minimize(&mut self, obj: LinExpr) {
        self.objective = obj;
    }

    pub fn n_vars(&self) -> usize {
        self.vars.len()
    }

    pub fn n_int_vars(&self) -> usize {
        self.vars.iter().filter(|v| v.integer).count()
    }

    pub fn n_constraints(&self) -> usize {
        self.constraints.len()
    }

    /// Is `x` feasible within tolerance?
    pub fn feasible(&self, x: &[f64], tol: f64) -> bool {
        if x.len() != self.vars.len() {
            return false;
        }
        for (i, v) in self.vars.iter().enumerate() {
            if x[i] < v.lo - tol || x[i] > v.hi + tol {
                return false;
            }
            if v.integer && (x[i] - x[i].round()).abs() > tol {
                return false;
            }
        }
        self.constraints.iter().all(|c| {
            let lhs = c.expr.eval(x);
            match c.cmp {
                Cmp::Le => lhs <= c.rhs + tol,
                Cmp::Ge => lhs >= c.rhs - tol,
                Cmp::Eq => (lhs - c.rhs).abs() <= tol,
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_and_check_feasibility() {
        let mut m = Model::new();
        let x = m.add_var("x", 0.0, 10.0);
        let y = m.add_bin("y");
        m.constrain(LinExpr::new().term(x, 1.0).term(y, 5.0), Cmp::Le, 8.0);
        m.minimize(LinExpr::new().term(x, -1.0));
        assert_eq!(m.n_vars(), 2);
        assert_eq!(m.n_int_vars(), 1);
        assert!(m.feasible(&[3.0, 1.0], 1e-6));
        assert!(!m.feasible(&[4.0, 1.0], 1e-6)); // 4 + 5 > 8
        assert!(!m.feasible(&[3.0, 0.5], 1e-6)); // fractional binary
        assert!(!m.feasible(&[11.0, 0.0], 1e-6)); // bound violation
    }

    #[test]
    fn expr_eval() {
        let e = LinExpr::new().term(0, 2.0).term(1, -1.0);
        assert_eq!(e.eval(&[3.0, 4.0]), 2.0);
    }
}

//! Integer-linear-programming substrate (§IV-D).
//!
//! The paper solves both leaf sub-problems with ILP ("a highly effective
//! method ... shown to provide near-optimal solutions given enough time").
//! No external solver is vendorable offline, so this module implements the
//! whole stack from scratch:
//!
//! * [`model`] — LP/MILP model builder (variables, bounds, integrality,
//!   linear constraints, minimisation objective),
//! * [`simplex`] — dense two-phase primal simplex with Bland's rule,
//! * [`bb`] — branch-and-bound MILP driver with deadline + incumbent,
//! * [`order_ilp`] — the paper's operator-ordering formulation (per-tensor
//!   creation/preservation variables `C`/`P`),
//! * [`layout_ilp`] — the DSA formulation (offset variables + pairwise
//!   above/below binaries with big-M non-overlap constraints).
//!
//! Scale expectations are part of the reproduction: these formulations are
//! solvable for leaf-sized subgraphs (tens of ops) and blow up on whole
//! training graphs — `order_ilp::formulation_size` reproduces the paper's
//! "more than 22 million integer decision variables" observation for
//! GPT2-XL (§V-D) without attempting the hopeless solve. The combinatorial
//! solvers ([`crate::sched::bnb`], [`crate::layout::dsa`]) are the
//! production path; the ILPs cross-validate them on small instances.

pub mod bb;
pub mod layout_ilp;
pub mod model;
pub mod order_ilp;
pub mod simplex;

pub use bb::{solve_milp, MilpCfg, MilpResult, MilpStatus};
pub use model::{Cmp, LinExpr, Model, VarId};

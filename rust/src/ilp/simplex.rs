//! Dense two-phase primal simplex.
//!
//! Solves the LP relaxation of a [`Model`]: variables are shifted to
//! `x' = x − lo ≥ 0`, upper bounds become explicit `≤` rows, all rows get
//! slack/surplus variables, phase 1 drives artificial variables out, phase
//! 2 optimises the real objective. Bland's rule guarantees termination.
//!
//! Dense tableaus are O((m+n)·n) per pivot — plenty for the leaf-sized
//! formulations ROAM feeds it, and *intentionally* hopeless for
//! whole-training-graph formulations (that asymmetry is the phenomenon the
//! paper measures; see `ilp::order_ilp::formulation_size`).

use super::model::{Cmp, Model};

/// LP outcome.
#[derive(Clone, Debug, PartialEq)]
pub enum LpStatus {
    Optimal,
    Infeasible,
    Unbounded,
    /// Iteration limit hit (numerical safety valve).
    IterLimit,
}

/// LP solution.
#[derive(Clone, Debug)]
pub struct LpSolution {
    pub status: LpStatus,
    /// Variable values in the original (unshifted) space.
    pub x: Vec<f64>,
    pub objective: f64,
}

const EPS: f64 = 1e-9;

/// Solve the LP relaxation of `m` (integrality ignored).
pub fn solve_lp(m: &Model) -> LpSolution {
    let n = m.vars.len();

    // Build rows: original constraints (shifted) + upper-bound rows.
    // Shifted var x' = x - lo, so a row Σ c x cmp b becomes Σ c x' cmp b - Σ c·lo.
    struct Row {
        coefs: Vec<f64>, // dense over n structural vars
        cmp: Cmp,
        rhs: f64,
    }
    let mut rows: Vec<Row> = Vec::with_capacity(m.constraints.len() + n);
    for c in &m.constraints {
        let mut coefs = vec![0.0; n];
        let mut shift = 0.0;
        for &(v, k) in &c.expr.terms {
            coefs[v] += k;
            shift += k * m.vars[v].lo;
        }
        rows.push(Row {
            coefs,
            cmp: c.cmp,
            rhs: c.rhs - shift,
        });
    }
    for (i, v) in m.vars.iter().enumerate() {
        if v.hi.is_finite() {
            let mut coefs = vec![0.0; n];
            coefs[i] = 1.0;
            rows.push(Row {
                coefs,
                cmp: Cmp::Le,
                rhs: v.hi - v.lo,
            });
        }
    }
    // Normalise RHS to be ≥ 0 by negating rows.
    for r in rows.iter_mut() {
        if r.rhs < 0.0 {
            for c in r.coefs.iter_mut() {
                *c = -*c;
            }
            r.rhs = -r.rhs;
            r.cmp = match r.cmp {
                Cmp::Le => Cmp::Ge,
                Cmp::Ge => Cmp::Le,
                Cmp::Eq => Cmp::Eq,
            };
        }
    }

    let m_rows = rows.len();
    // Column layout: [structural n][slack/surplus s][artificial a][rhs]
    let n_slack = rows
        .iter()
        .filter(|r| matches!(r.cmp, Cmp::Le | Cmp::Ge))
        .count();
    // Artificials for Ge and Eq rows.
    let n_art = rows
        .iter()
        .filter(|r| matches!(r.cmp, Cmp::Ge | Cmp::Eq))
        .count();
    let total = n + n_slack + n_art;
    let mut t = vec![vec![0.0f64; total + 1]; m_rows];
    let mut basis = vec![0usize; m_rows];
    let mut si = n;
    let mut ai = n + n_slack;
    for (r, row) in rows.iter().enumerate() {
        t[r][..n].copy_from_slice(&row.coefs);
        t[r][total] = row.rhs;
        match row.cmp {
            Cmp::Le => {
                t[r][si] = 1.0;
                basis[r] = si;
                si += 1;
            }
            Cmp::Ge => {
                t[r][si] = -1.0;
                si += 1;
                t[r][ai] = 1.0;
                basis[r] = ai;
                ai += 1;
            }
            Cmp::Eq => {
                t[r][ai] = 1.0;
                basis[r] = ai;
                ai += 1;
            }
        }
    }

    let max_iters = 50 * (m_rows + total).max(100);

    // Phase 1: minimise sum of artificials.
    if n_art > 0 {
        let mut z = vec![0.0f64; total + 1];
        for (r, &b) in basis.iter().enumerate() {
            if b >= n + n_slack {
                for c in 0..=total {
                    z[c] += t[r][c];
                }
            }
        }
        // Reduced costs for phase 1: cost 1 on artificials.
        // z currently holds Σ (artificial rows); reduced cost of col j =
        // z[j] (since c_j = 0 for non-artificial, 1 for artificial basic).
        match pivot_loop(&mut t, &mut basis, &mut z, total, n + n_slack, max_iters) {
            PivotOutcome::Optimal => {}
            PivotOutcome::Unbounded => {
                return LpSolution {
                    status: LpStatus::Infeasible,
                    x: vec![0.0; n],
                    objective: f64::NAN,
                }
            }
            PivotOutcome::IterLimit => {
                return LpSolution {
                    status: LpStatus::IterLimit,
                    x: vec![0.0; n],
                    objective: f64::NAN,
                }
            }
        }
        if z[total] > 1e-6 {
            return LpSolution {
                status: LpStatus::Infeasible,
                x: vec![0.0; n],
                objective: f64::NAN,
            };
        }
        // Drive any remaining artificial out of the basis if possible.
        for r in 0..m_rows {
            if basis[r] >= n + n_slack {
                if let Some(j) = (0..n + n_slack).find(|&j| t[r][j].abs() > EPS) {
                    do_pivot(&mut t, &mut basis, r, j, total);
                }
            }
        }
    }

    // Phase 2: real objective (shifted space). minimize c^T x.
    let mut cost = vec![0.0f64; total + 1];
    for &(v, k) in &m.objective.terms {
        cost[v] += k;
    }
    // Reduced-cost row: z_j - c_j form. Start with -c then add back basics.
    let mut z = vec![0.0f64; total + 1];
    for j in 0..=total {
        z[j] = -cost[j];
    }
    for (r, &b) in basis.iter().enumerate() {
        if cost[b] != 0.0 {
            let f = cost[b];
            for c in 0..=total {
                z[c] += f * t[r][c];
            }
        }
    }
    let limit_cols = n + n_slack; // artificials barred from re-entering
    let status = match pivot_loop_max(&mut t, &mut basis, &mut z, total, limit_cols, max_iters) {
        PivotOutcome::Optimal => LpStatus::Optimal,
        PivotOutcome::Unbounded => LpStatus::Unbounded,
        PivotOutcome::IterLimit => LpStatus::IterLimit,
    };

    // Extract solution (unshift).
    let mut x = vec![0.0f64; n];
    for (r, &b) in basis.iter().enumerate() {
        if b < n {
            x[b] = t[r][total];
        }
    }
    for (i, v) in m.vars.iter().enumerate() {
        x[i] += v.lo;
    }
    let objective = m.objective.eval(&x);
    LpSolution {
        status,
        x,
        objective,
    }
}

enum PivotOutcome {
    Optimal,
    Unbounded,
    IterLimit,
}

/// Phase-1 loop: minimise (z row holds positive reduced costs to shrink).
/// Entering column: any with z[j] > EPS (Bland: smallest index).
fn pivot_loop(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    z: &mut [f64],
    total: usize,
    limit_cols: usize,
    max_iters: usize,
) -> PivotOutcome {
    for _ in 0..max_iters {
        let Some(j) = (0..limit_cols).find(|&j| z[j] > EPS) else {
            return PivotOutcome::Optimal;
        };
        match ratio_test(t, j, total) {
            None => return PivotOutcome::Unbounded,
            Some(r) => {
                do_pivot(t, basis, r, j, total);
                update_z(z, t, r, j, total);
            }
        }
    }
    PivotOutcome::IterLimit
}

/// Phase-2 loop for a minimisation written as z_j - c_j: entering column has
/// z[j] > EPS as well (same convention as phase 1, objective decreases).
fn pivot_loop_max(
    t: &mut [Vec<f64>],
    basis: &mut [usize],
    z: &mut [f64],
    total: usize,
    limit_cols: usize,
    max_iters: usize,
) -> PivotOutcome {
    pivot_loop(t, basis, z, total, limit_cols, max_iters)
}

fn ratio_test(t: &[Vec<f64>], j: usize, total: usize) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for (r, row) in t.iter().enumerate() {
        if row[j] > EPS {
            let ratio = row[total] / row[j];
            match best {
                None => best = Some((ratio, r)),
                Some((br, _)) if ratio < br - EPS => best = Some((ratio, r)),
                _ => {}
            }
        }
    }
    best.map(|(_, r)| r)
}

fn do_pivot(t: &mut [Vec<f64>], basis: &mut [usize], r: usize, j: usize, total: usize) {
    let piv = t[r][j];
    for c in 0..=total {
        t[r][c] /= piv;
    }
    for rr in 0..t.len() {
        if rr != r && t[rr][j].abs() > EPS {
            let f = t[rr][j];
            for c in 0..=total {
                t[rr][c] -= f * t[r][c];
            }
        }
    }
    basis[r] = j;
}

fn update_z(z: &mut [f64], t: &[Vec<f64>], r: usize, j: usize, total: usize) {
    let f = z[j];
    if f.abs() > EPS {
        for c in 0..=total {
            z[c] -= f * t[r][c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ilp::model::{Cmp, LinExpr, Model};

    #[test]
    fn simple_min() {
        // min -x - 2y  s.t. x + y <= 4, x <= 3, y <= 2, x,y >= 0.
        let mut m = Model::new();
        let x = m.add_var("x", 0.0, 3.0);
        let y = m.add_var("y", 0.0, 2.0);
        m.constrain(LinExpr::new().term(x, 1.0).term(y, 1.0), Cmp::Le, 4.0);
        m.minimize(LinExpr::new().term(x, -1.0).term(y, -2.0));
        let s = solve_lp(&m);
        assert_eq!(s.status, LpStatus::Optimal);
        // Optimum: y = 2, x = 2, obj = -6.
        assert!((s.objective - (-6.0)).abs() < 1e-6, "obj = {}", s.objective);
        assert!((s.x[x] - 2.0).abs() < 1e-6);
        assert!((s.x[y] - 2.0).abs() < 1e-6);
    }

    #[test]
    fn equality_and_ge() {
        // min x + y  s.t. x + y = 5, x >= 2.
        let mut m = Model::new();
        let x = m.add_var("x", 0.0, 100.0);
        let y = m.add_var("y", 0.0, 100.0);
        m.constrain(LinExpr::new().term(x, 1.0).term(y, 1.0), Cmp::Eq, 5.0);
        m.constrain(LinExpr::var(x), Cmp::Ge, 2.0);
        m.minimize(LinExpr::new().term(x, 1.0).term(y, 1.0));
        let s = solve_lp(&m);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.objective - 5.0).abs() < 1e-6);
        assert!(s.x[x] >= 2.0 - 1e-6);
    }

    #[test]
    fn infeasible_detected() {
        let mut m = Model::new();
        let x = m.add_var("x", 0.0, 1.0);
        m.constrain(LinExpr::var(x), Cmp::Ge, 2.0);
        m.minimize(LinExpr::var(x));
        assert_eq!(solve_lp(&m).status, LpStatus::Infeasible);
    }

    #[test]
    fn nonzero_lower_bounds() {
        // min x  s.t. x >= 0, 3 <= x <= 7  → x = 3.
        let mut m = Model::new();
        let x = m.add_var("x", 3.0, 7.0);
        m.minimize(LinExpr::var(x));
        let s = solve_lp(&m);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.x[x] - 3.0).abs() < 1e-6);
    }

    #[test]
    fn respects_upper_bounds() {
        // max x (min -x) with x ≤ 5 via bound only.
        let mut m = Model::new();
        let x = m.add_var("x", 0.0, 5.0);
        m.minimize(LinExpr::new().term(x, -1.0));
        let s = solve_lp(&m);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!((s.x[x] - 5.0).abs() < 1e-6);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // Klee-Minty-ish tiny degenerate case; Bland must terminate.
        let mut m = Model::new();
        let x = m.add_var("x", 0.0, 10.0);
        let y = m.add_var("y", 0.0, 10.0);
        m.constrain(LinExpr::new().term(x, 1.0).term(y, 1.0), Cmp::Le, 0.0);
        m.minimize(LinExpr::new().term(x, -1.0).term(y, -1.0));
        let s = solve_lp(&m);
        assert_eq!(s.status, LpStatus::Optimal);
        assert!(s.objective.abs() < 1e-6);
    }
}

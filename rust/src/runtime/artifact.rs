//! Artifact directory: HLO text files + `meta.json` written by
//! `python/compile/aot.py`.

use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Metadata of an AOT-compiled model bundle.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layer: usize,
    pub n_head: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub param_count: u64,
    /// File names of the lowered computations.
    pub train_step: String,
    pub init: String,
}

/// An artifact bundle on disk.
#[derive(Clone, Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub meta: ArtifactMeta,
}

impl Artifacts {
    /// Load `meta.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {}", meta_path.display()))?;
        let j = Json::parse(&text).map_err(|e| err!("meta.json: {e}"))?;
        let num = |k: &str| -> Result<u64> {
            j.get(k)
                .and_then(|v| v.as_u64())
                .ok_or_else(|| err!("meta.json missing numeric field '{k}'"))
        };
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(|x| x.to_string())
                .ok_or_else(|| err!("meta.json missing string field '{k}'"))
        };
        Ok(Artifacts {
            dir: dir.to_path_buf(),
            meta: ArtifactMeta {
                vocab: num("vocab")? as usize,
                d_model: num("d_model")? as usize,
                n_layer: num("n_layer")? as usize,
                n_head: num("n_head")? as usize,
                seq_len: num("seq_len")? as usize,
                batch: num("batch")? as usize,
                param_count: num("param_count")?,
                train_step: s("train_step")?,
                init: s("init")?,
            },
        })
    }

    pub fn train_step_path(&self) -> PathBuf {
        self.dir.join(&self.meta.train_step)
    }

    pub fn init_path(&self) -> PathBuf {
        self.dir.join(&self.meta.init)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_roundtrip() {
        let dir = std::env::temp_dir().join("roam_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("meta.json"),
            r#"{"vocab": 8192, "d_model": 768, "n_layer": 12, "n_head": 12,
                "seq_len": 128, "batch": 4, "param_count": 91000000,
                "train_step": "train_step.hlo.txt", "init": "init.hlo.txt"}"#,
        )
        .unwrap();
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.meta.vocab, 8192);
        assert_eq!(a.meta.param_count, 91_000_000);
        assert!(a.train_step_path().ends_with("train_step.hlo.txt"));
    }

    #[test]
    fn missing_fields_error() {
        let dir = std::env::temp_dir().join("roam_meta_bad");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("meta.json"), r#"{"vocab": 1}"#).unwrap();
        assert!(Artifacts::load(&dir).is_err());
    }
}

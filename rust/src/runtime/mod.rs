//! PJRT runtime: load AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`) and execute them on the CPU PJRT client.
//!
//! Python never runs on the training path: `make artifacts` lowers the L2
//! JAX train step once; this module compiles the text with the `xla` crate
//! (`HloModuleProto::from_text_file` → `XlaComputation` → `compile`) and
//! the coordinator drives `execute` in a loop. HLO *text* is the
//! interchange format — jax ≥ 0.5 emits protos with 64-bit instruction ids
//! that xla_extension 0.5.1 rejects; the text parser reassigns ids.

pub mod artifact;

use crate::util::error::{Context, Result};
use std::path::Path;

/// A compiled computation ready to execute.
pub struct LoadedModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT client plus the modules loaded from an artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    /// CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// Platform string for logs.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO text file.
    pub fn load_hlo_text(&self, path: &Path) -> Result<LoadedModule> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(LoadedModule {
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_default(),
            exe,
        })
    }

    /// Read an HLO text file and parse it into a ROAM graph for planning.
    pub fn parse_graph(&self, path: &Path) -> Result<crate::graph::Graph> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        crate::hlo::parse_hlo_text(&text).map_err(crate::util::error::Error::from)
    }
}

impl LoadedModule {
    /// Underlying executable (for call styles defined in other modules).
    pub fn exe_ref(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }

    /// Execute with literal inputs; returns the output tuple's elements.
    ///
    /// JAX computations are lowered with `return_tuple=True`, so the result
    /// is one tuple literal which this method decomposes.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self.exe.execute::<xla::Literal>(args)?;
        let lit = bufs[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        Ok(lit.to_tuple()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A tiny HLO module written by hand: f(x, y) = (x·y + 2,) over
    /// f32[2,2] — the same computation as /opt/xla-example.
    const HLO: &str = r#"HloModule jit_fn

ENTRY %main.9 (Arg_0.1: f32[2,2], Arg_1.2: f32[2,2]) -> (f32[2,2]) {
  %Arg_0.1 = f32[2,2]{1,0} parameter(0)
  %Arg_1.2 = f32[2,2]{1,0} parameter(1)
  %dot.3 = f32[2,2]{1,0} dot(f32[2,2]{1,0} %Arg_0.1, f32[2,2]{1,0} %Arg_1.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %constant.4 = f32[] constant(2)
  %broadcast.5 = f32[2,2]{1,0} broadcast(f32[] %constant.4), dimensions={}
  %add.6 = f32[2,2]{1,0} add(f32[2,2]{1,0} %dot.3, f32[2,2]{1,0} %broadcast.5)
  ROOT %tuple.8 = (f32[2,2]{1,0}) tuple(f32[2,2]{1,0} %add.6)
}
"#;

    #[test]
    fn load_and_execute_roundtrip() {
        let dir = std::env::temp_dir().join("roam_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fn.hlo.txt");
        std::fs::write(&path, HLO).unwrap();

        let rt = Runtime::cpu().expect("cpu client");
        assert!(!rt.platform().is_empty());
        let m = rt.load_hlo_text(&path).expect("compile");
        let x = xla::Literal::vec1(&[1f32, 2., 3., 4.]).reshape(&[2, 2]).unwrap();
        let y = xla::Literal::vec1(&[1f32, 1., 1., 1.]).reshape(&[2, 2]).unwrap();
        let out = m.run(&[x, y]).expect("execute");
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        assert_eq!(v, vec![5., 5., 9., 9.]);

        // The same artifact parses into a plannable graph.
        let g = rt.parse_graph(&path).expect("parse");
        assert_eq!(g.n_ops(), 5);
    }
}

//! Model-graph builders for the paper's evaluation suite (§V-A).
//!
//! The paper traces PyTorch models with torch.FX and optimizes the resulting
//! training graphs. We reproduce the same graphs synthetically with
//! byte-accurate tensor sizes and FX-level op granularity (see
//! [`builder`]). Eight models, matching §V-A:
//!
//! * CNNs: AlexNet, VGG-16, MnasNet-B1, MobileNetV1, EfficientNet-B0
//! * Transformers: ViT-B/16, BERT-base
//! * LLM: GPT2-XL (scalability evaluation, §V-D)
//!
//! plus [`ModelKind::SyntheticTransformer`] — a depth-parameterised encoder
//! used by the Fig-15 op-count scaling sweep.

pub mod builder;
pub mod cnn;
pub mod mobile;
pub mod transformer;

pub use builder::{NetBuilder, Optim, TRef};

use crate::graph::Graph;

/// The evaluation models.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ModelKind {
    Alexnet,
    Vgg16,
    Mnasnet,
    Mobilenet,
    Efficientnet,
    Vit,
    Bert,
    Gpt2Xl,
    /// Parameterised encoder for scaling sweeps (layers = `BuildCfg::depth`).
    SyntheticTransformer,
}

impl ModelKind {
    /// The seven "small" models of Figures 11–14 / Table I.
    pub fn eval_suite() -> &'static [ModelKind] {
        &[
            ModelKind::Alexnet,
            ModelKind::Vgg16,
            ModelKind::Mnasnet,
            ModelKind::Mobilenet,
            ModelKind::Efficientnet,
            ModelKind::Vit,
            ModelKind::Bert,
        ]
    }

    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Alexnet => "alexnet",
            ModelKind::Vgg16 => "vgg16",
            ModelKind::Mnasnet => "mnasnet",
            ModelKind::Mobilenet => "mobilenet",
            ModelKind::Efficientnet => "efficientnet",
            ModelKind::Vit => "vit",
            ModelKind::Bert => "bert",
            ModelKind::Gpt2Xl => "gpt2-xl",
            ModelKind::SyntheticTransformer => "synthetic-transformer",
        }
    }

    /// Parse a CLI name.
    pub fn from_name(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "alexnet" => Some(ModelKind::Alexnet),
            "vgg" | "vgg16" => Some(ModelKind::Vgg16),
            "mnasnet" => Some(ModelKind::Mnasnet),
            "mobilenet" => Some(ModelKind::Mobilenet),
            "efficientnet" => Some(ModelKind::Efficientnet),
            "vit" => Some(ModelKind::Vit),
            "bert" => Some(ModelKind::Bert),
            "gpt2-xl" | "gpt2xl" | "gpt2" => Some(ModelKind::Gpt2Xl),
            "synthetic-transformer" | "synthetic" => Some(ModelKind::SyntheticTransformer),
            _ => None,
        }
    }
}

/// Build configuration: batch size and optimizer match the paper's setup
/// (batch ∈ {1, 32} for the small models, {1, 2, 4} for GPT2-XL; Adam).
#[derive(Clone, Debug)]
pub struct BuildCfg {
    pub batch: usize,
    pub optim: Optim,
    /// Sequence length for the language models (BERT: 128, GPT2-XL: 1024).
    pub seq_len: Option<usize>,
    /// Encoder depth for `SyntheticTransformer`.
    pub depth: usize,
    /// Decompose layernorm / softmax / gelu into primitive ops (FX-level
    /// granularity; on by default — this is what the traced graphs contain).
    pub fine_grained: bool,
}

impl Default for BuildCfg {
    fn default() -> Self {
        BuildCfg {
            batch: 1,
            optim: Optim::Adam,
            seq_len: None,
            depth: 12,
            fine_grained: true,
        }
    }
}

/// Build a model's training graph.
pub fn build(kind: ModelKind, cfg: &BuildCfg) -> Graph {
    match kind {
        ModelKind::Alexnet => cnn::alexnet(cfg),
        ModelKind::Vgg16 => cnn::vgg16(cfg),
        ModelKind::Mnasnet => mobile::mnasnet(cfg),
        ModelKind::Mobilenet => mobile::mobilenet_v1(cfg),
        ModelKind::Efficientnet => mobile::efficientnet_b0(cfg),
        ModelKind::Vit => transformer::vit_b16(cfg),
        ModelKind::Bert => transformer::bert_base(cfg),
        ModelKind::Gpt2Xl => transformer::gpt2_xl(cfg),
        ModelKind::SyntheticTransformer => transformer::synthetic(cfg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;

    #[test]
    fn names_roundtrip() {
        for &k in ModelKind::eval_suite() {
            assert_eq!(ModelKind::from_name(k.name()), Some(k));
        }
        assert_eq!(ModelKind::from_name("gpt2-xl"), Some(ModelKind::Gpt2Xl));
        assert_eq!(ModelKind::from_name("nope"), None);
    }

    #[test]
    fn all_small_models_build_and_validate() {
        for &k in ModelKind::eval_suite() {
            let g = build(k, &BuildCfg { batch: 1, ..Default::default() });
            let defects = validate(&g);
            assert!(defects.is_empty(), "{}: {:?}", k.name(), &defects[..defects.len().min(5)]);
            assert!(g.n_ops() > 20, "{} too small: {} ops", k.name(), g.n_ops());
        }
    }
}

//! Mobile CNN builders: MobileNetV1, MnasNet-B1 and EfficientNet-B0.
//!
//! These models stress the planner differently from AlexNet/VGG: many more
//! operators (depthwise-separable blocks, squeeze-and-excitation gates),
//! lots of small batch-norm statistics tensors alongside large feature
//! maps — exactly the "diverse tensor sizes" regime where the paper shows
//! LESCEA and LLFB degrade (§V-B).

use super::builder::{NetBuilder, TRef};
use super::BuildCfg;
use crate::graph::Graph;

/// Depthwise-separable block (MobileNetV1): dw3x3 + BN + ReLU, pw1x1 + BN + ReLU.
fn dw_separable(b: &mut NetBuilder, x: &TRef, out_c: usize, stride: usize, tag: &str) -> TRef {
    let d = b.dwconv2d(x, 3, stride, 1, &format!("{tag}.dw"));
    let d = b.batchnorm(&d, &format!("{tag}.bn1"));
    let d = b.relu(&d);
    let p = b.conv2d(&d, out_c, 1, 1, 0, &format!("{tag}.pw"));
    let p = b.batchnorm(&p, &format!("{tag}.bn2"));
    b.relu(&p)
}

/// MobileNetV1 (Howard et al. 2017), width 1.0, training graph.
pub fn mobilenet_v1(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let mut b = NetBuilder::new(format!("mobilenet_bs{n}"));
    let x = b.input("images", &[n, 3, 224, 224]);
    let y = b.input("labels", &[n]);

    let c = b.conv2d(&x, 32, 3, 2, 1, "stem");
    let c = b.batchnorm(&c, "stem.bn");
    let mut h = b.relu(&c);

    // (out_channels, stride) for the 13 separable blocks.
    let blocks: [(usize, usize); 13] = [
        (64, 1),
        (128, 2),
        (128, 1),
        (256, 2),
        (256, 1),
        (512, 2),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (512, 1),
        (1024, 2),
        (1024, 1),
    ];
    for (i, &(c, s)) in blocks.iter().enumerate() {
        h = dw_separable(&mut b, &h, c, s, &format!("blocks.{i}"));
    }

    let g = b.gap(&h);
    let l = b.linear(&g, 1000, "classifier");
    b.cross_entropy(&l, &y);
    b.finish_training(cfg.optim)
}

/// Squeeze-and-excitation gate: gap → fc(reduce) → swish → fc(expand) →
/// sigmoid → broadcast-multiply.
fn se_block(b: &mut NetBuilder, x: &TRef, se_c: usize, tag: &str) -> TRef {
    let s = b.gap(x); // (N, C)
    let f1 = b.linear(&s, se_c, &format!("{tag}.fc1"));
    let a1 = b.swish(&f1);
    let c = x.shape[1];
    let f2 = b.linear(&a1, c, &format!("{tag}.fc2"));
    let gate = b.sigmoid(&f2); // (N, C)
    b.bcast(x, &gate, &format!("{tag}.scale"))
}

/// Mobile inverted-bottleneck block (MBConv), optionally with SE.
#[allow(clippy::too_many_arguments)]
fn mbconv(
    b: &mut NetBuilder,
    x: &TRef,
    out_c: usize,
    expand: usize,
    k: usize,
    stride: usize,
    se_ratio: Option<f64>,
    swish: bool,
    tag: &str,
) -> TRef {
    let in_c = x.shape[1];
    let exp_c = in_c * expand;
    let mut h = x.clone();
    if expand != 1 {
        let e = b.conv2d(&h, exp_c, 1, 1, 0, &format!("{tag}.expand"));
        let e = b.batchnorm(&e, &format!("{tag}.bn0"));
        h = if swish { b.swish(&e) } else { b.relu(&e) };
    }
    let d = b.dwconv2d(&h, k, stride, k / 2, &format!("{tag}.dw"));
    let d = b.batchnorm(&d, &format!("{tag}.bn1"));
    let mut h = if swish { b.swish(&d) } else { b.relu(&d) };
    if let Some(r) = se_ratio {
        let se_c = ((in_c as f64) * r).max(1.0) as usize;
        h = se_block(b, &h, se_c, &format!("{tag}.se"));
    }
    let p = b.conv2d(&h, out_c, 1, 1, 0, &format!("{tag}.project"));
    let p = b.batchnorm(&p, &format!("{tag}.bn2"));
    if stride == 1 && in_c == out_c {
        b.add(&p, x)
    } else {
        p
    }
}

/// MnasNet-B1 (Tan et al. 2019, no SE), training graph.
pub fn mnasnet(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let mut b = NetBuilder::new(format!("mnasnet_bs{n}"));
    let x = b.input("images", &[n, 3, 224, 224]);
    let y = b.input("labels", &[n]);

    let c = b.conv2d(&x, 32, 3, 2, 1, "stem");
    let c = b.batchnorm(&c, "stem.bn");
    let mut h = b.relu(&c);
    // Initial separable conv to 16 channels.
    h = dw_separable(&mut b, &h, 16, 1, "sep");

    // (out_c, expand, kernel, stride, repeats) per stage (B1).
    let stages: [(usize, usize, usize, usize, usize); 6] = [
        (24, 3, 3, 2, 3),
        (40, 3, 5, 2, 3),
        (80, 6, 5, 2, 3),
        (96, 6, 3, 1, 2),
        (192, 6, 5, 2, 4),
        (320, 6, 3, 1, 1),
    ];
    for (si, &(oc, ex, k, s, reps)) in stages.iter().enumerate() {
        for r in 0..reps {
            let stride = if r == 0 { s } else { 1 };
            h = mbconv(&mut b, &h, oc, ex, k, stride, None, false, &format!("s{si}.b{r}"));
        }
    }

    let c = b.conv2d(&h, 1280, 1, 1, 0, "head");
    let c = b.batchnorm(&c, "head.bn");
    let h = b.relu(&c);
    let g = b.gap(&h);
    let l = b.linear(&g, 1000, "classifier");
    b.cross_entropy(&l, &y);
    b.finish_training(cfg.optim)
}

/// EfficientNet-B0 (Tan & Le 2019) with SE and swish, training graph.
pub fn efficientnet_b0(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let mut b = NetBuilder::new(format!("efficientnet_bs{n}"));
    let x = b.input("images", &[n, 3, 224, 224]);
    let y = b.input("labels", &[n]);

    let c = b.conv2d(&x, 32, 3, 2, 1, "stem");
    let c = b.batchnorm(&c, "stem.bn");
    let mut h = b.swish(&c);

    // (out_c, expand, kernel, stride, repeats) — the B0 configuration.
    let stages: [(usize, usize, usize, usize, usize); 7] = [
        (16, 1, 3, 1, 1),
        (24, 6, 3, 2, 2),
        (40, 6, 5, 2, 2),
        (80, 6, 3, 2, 3),
        (112, 6, 5, 1, 3),
        (192, 6, 5, 2, 4),
        (320, 6, 3, 1, 1),
    ];
    for (si, &(oc, ex, k, s, reps)) in stages.iter().enumerate() {
        for r in 0..reps {
            let stride = if r == 0 { s } else { 1 };
            h = mbconv(
                &mut b,
                &h,
                oc,
                ex,
                k,
                stride,
                Some(0.25),
                true,
                &format!("s{si}.b{r}"),
            );
        }
    }

    let c = b.conv2d(&h, 1280, 1, 1, 0, "head");
    let c = b.batchnorm(&c, "head.bn");
    let h = b.swish(&c);
    let g = b.gap(&h);
    let d = b.dropout(&g, "head.drop");
    let l = b.linear(&d, 1000, "classifier");
    b.cross_entropy(&l, &y);
    b.finish_training(cfg.optim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::graph::OpKind;
    use crate::models::BuildCfg;

    fn cfg(batch: usize) -> BuildCfg {
        BuildCfg {
            batch,
            ..Default::default()
        }
    }

    #[test]
    fn mobilenet_valid_and_sized() {
        let g = mobilenet_v1(&cfg(1));
        assert!(validate(&g).is_empty());
        // 13 blocks * ~6 fwd ops + stem + head; training triples it.
        assert!(g.n_ops() > 300, "got {}", g.n_ops());
    }

    #[test]
    fn mnasnet_has_residuals() {
        let g = mnasnet(&cfg(1));
        assert!(validate(&g).is_empty());
        assert!(g.ops.iter().any(|o| o.kind == OpKind::GradAcc),
            "residual blocks must create gradient accumulation");
    }

    #[test]
    fn efficientnet_has_se_gates() {
        let g = efficientnet_b0(&cfg(1));
        assert!(validate(&g).is_empty());
        assert!(g.ops.iter().any(|o| o.name.contains(".se.")));
        // EfficientNet-B0 is the biggest mobile net here by op count.
        assert!(g.n_ops() > mnasnet(&cfg(1)).n_ops() / 2);
    }

    #[test]
    fn spatial_dims_shrink_to_7x7() {
        // Head feature map must be 7x7 for 224 inputs in all three nets —
        // a shape-arithmetic regression test for conv/pool chains.
        let g = efficientnet_b0(&cfg(1));
        let head = g
            .tensors
            .iter()
            .find(|t| t.name.contains("head.conv") || t.name.contains("head"))
            .unwrap();
        // 1280 * 7 * 7 * 4 bytes = 250880 per sample appears in the head.
        assert!(head.size >= 1280 * 7 * 7 * 4 || head.size >= 4);
        let _ = head;
    }
}

//! Classic CNN builders: AlexNet and VGG-16 (ImageNet-shaped inputs).
//!
//! These are the paper's "small" CNNs. Both use 3×224×224 inputs and a
//! 1000-class cross-entropy head; AlexNet keeps its two dropout layers
//! (their masks are forward activations consumed in backward, which is part
//! of the memory story).

use super::builder::NetBuilder;
use super::BuildCfg;
use crate::graph::Graph;

/// AlexNet (Krizhevsky et al. 2012), training graph.
pub fn alexnet(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let mut b = NetBuilder::new(format!("alexnet_bs{n}"));
    let x = b.input("images", &[n, 3, 224, 224]);
    let y = b.input("labels", &[n]);

    let c1 = b.conv2d(&x, 64, 11, 4, 2, "features.0");
    let r1 = b.relu(&c1);
    let p1 = b.pool2d(&r1, 3, 2, "features.2");
    let c2 = b.conv2d(&p1, 192, 5, 1, 2, "features.3");
    let r2 = b.relu(&c2);
    let p2 = b.pool2d(&r2, 3, 2, "features.5");
    let c3 = b.conv2d(&p2, 384, 3, 1, 1, "features.6");
    let r3 = b.relu(&c3);
    let c4 = b.conv2d(&r3, 256, 3, 1, 1, "features.8");
    let r4 = b.relu(&c4);
    let c5 = b.conv2d(&r4, 256, 3, 1, 1, "features.10");
    let r5 = b.relu(&c5);
    let p5 = b.pool2d(&r5, 3, 2, "features.12");

    let f = b.flatten(&p5); // 256*6*6 = 9216
    let d1 = b.dropout(&f, "classifier.drop1");
    let l1 = b.linear(&d1, 4096, "classifier.1");
    let r6 = b.relu(&l1);
    let d2 = b.dropout(&r6, "classifier.drop2");
    let l2 = b.linear(&d2, 4096, "classifier.4");
    let r7 = b.relu(&l2);
    let l3 = b.linear(&r7, 1000, "classifier.6");
    b.cross_entropy(&l3, &y);
    b.finish_training(cfg.optim)
}

/// VGG-16 (configuration D), training graph with batch-norm-free blocks.
pub fn vgg16(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let mut b = NetBuilder::new(format!("vgg16_bs{n}"));
    let x = b.input("images", &[n, 3, 224, 224]);
    let y = b.input("labels", &[n]);

    // (out_channels, convs in block)
    let blocks: [(usize, usize); 5] = [(64, 2), (128, 2), (256, 3), (512, 3), (512, 3)];
    let mut h = x;
    for (bi, &(ch, reps)) in blocks.iter().enumerate() {
        for ri in 0..reps {
            let c = b.conv2d(&h, ch, 3, 1, 1, &format!("features.b{bi}c{ri}"));
            h = b.relu(&c);
        }
        h = b.pool2d(&h, 2, 2, &format!("features.pool{bi}"));
    }

    let f = b.flatten(&h); // 512*7*7 = 25088
    let l1 = b.linear(&f, 4096, "classifier.0");
    let r1 = b.relu(&l1);
    let d1 = b.dropout(&r1, "classifier.drop1");
    let l2 = b.linear(&d1, 4096, "classifier.3");
    let r2 = b.relu(&l2);
    let d2 = b.dropout(&r2, "classifier.drop2");
    let l3 = b.linear(&d2, 1000, "classifier.6");
    b.cross_entropy(&l3, &y);
    b.finish_training(cfg.optim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::graph::Phase;
    use crate::models::{BuildCfg, Optim};

    fn cfg(batch: usize) -> BuildCfg {
        BuildCfg {
            batch,
            ..Default::default()
        }
    }

    #[test]
    fn alexnet_structure() {
        let g = alexnet(&cfg(1));
        assert!(validate(&g).is_empty());
        // 5 convs + 3 linears ⇒ 8 weight+bias parameter pairs ⇒ 16 params.
        // Adam: 6 update ops each would be 96; our Fig-6 expansion is 4/param.
        let upd = g.ops_in_phase(Phase::Update).count();
        assert_eq!(upd % 16, 0, "updates must be a multiple of param count, got {upd}");
        assert!(g.n_ops() > 60);
    }

    #[test]
    fn vgg_larger_than_alexnet() {
        let a = alexnet(&cfg(1));
        let v = vgg16(&cfg(1));
        assert!(v.n_ops() > a.n_ops());
        assert!(v.persistent_bytes() > a.persistent_bytes());
    }

    #[test]
    fn batch_scales_activations_not_params() {
        let g1 = alexnet(&cfg(1));
        let g32 = alexnet(&cfg(32));
        assert_eq!(g1.persistent_bytes(), g32.persistent_bytes());
        assert!(g32.activation_bytes() > 20 * g1.activation_bytes());
        assert_eq!(g1.n_ops(), g32.n_ops());
    }

    #[test]
    fn sgd_smaller_than_adam() {
        let adam = alexnet(&cfg(1));
        let sgd = alexnet(&BuildCfg {
            batch: 1,
            optim: Optim::Sgd,
            ..Default::default()
        });
        assert!(sgd.n_ops() < adam.n_ops());
        assert!(sgd.persistent_bytes() < adam.persistent_bytes());
    }
}

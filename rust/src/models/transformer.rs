//! Transformer builders: ViT-B/16, BERT-base, GPT2-XL and the synthetic
//! depth-parameterised encoder used in scaling sweeps.
//!
//! Built at FX granularity: with `BuildCfg::fine_grained` (default) the
//! layernorm / softmax / gelu composites are decomposed into their
//! primitive ops (reductions, broadcasts, elementwise) exactly as a traced
//! PyTorch training graph shows them — this is what pushes the GPT2-XL
//! training graph towards the "more than 10,000 operators" regime the
//! paper's scalability evaluation targets (§V-D).

use super::builder::{NetBuilder, TRef};
use super::BuildCfg;
use crate::graph::Graph;

/// Encoder hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct TxSpec {
    pub d: usize,
    pub heads: usize,
    pub ffn: usize,
    pub layers: usize,
    pub seq: usize,
    pub causal: bool,
}

/// LayerNorm, optionally decomposed into FX-level primitives:
/// mean → subtract → square → variance → divide → scale(γ) → shift(β).
fn layernorm(b: &mut NetBuilder, x: &TRef, fine: bool, tag: &str) -> TRef {
    if !fine {
        return b.layernorm(x, tag);
    }
    let rows: usize = x.shape[..x.shape.len() - 1].iter().product();
    let rshape = vec![rows];
    let mean = b.reduce(x, &rshape, &format!("{tag}.mean"));
    let xc = b.bcast(x, &mean, &format!("{tag}.sub"));
    let sq = b.mul(&xc, &xc);
    let var = b.reduce(&sq, &rshape, &format!("{tag}.var"));
    let norm = b.bcast(&xc, &var, &format!("{tag}.div"));
    let d = *x.shape.last().unwrap();
    let gamma = b.param(&format!("{tag}.gamma"), &[d]);
    let beta = b.param(&format!("{tag}.beta"), &[d]);
    let scaled = b.bcast(&norm, &gamma, &format!("{tag}.scale"));
    b.bcast(&scaled, &beta, &format!("{tag}.shift"))
}

/// Softmax over the last dim, optionally decomposed:
/// max → subtract → exp → sum → divide.
fn softmax(b: &mut NetBuilder, x: &TRef, fine: bool, tag: &str) -> TRef {
    if !fine {
        return b.softmax(x);
    }
    let rows: usize = x.shape[..x.shape.len() - 1].iter().product();
    let rshape = vec![rows];
    let mx = b.reduce(x, &rshape, &format!("{tag}.max"));
    let sh = b.bcast(x, &mx, &format!("{tag}.submax"));
    let e = b.act(&sh, &format!("{tag}.exp"));
    let sm = b.reduce(&e, &rshape, &format!("{tag}.sum"));
    b.bcast(&e, &sm, &format!("{tag}.divsum"))
}

/// GELU (tanh approximation), optionally decomposed:
/// x² → x³ → tanh → gate-multiply.
fn gelu(b: &mut NetBuilder, x: &TRef, fine: bool) -> TRef {
    if !fine {
        return b.gelu(x);
    }
    let sq = b.mul(x, x);
    let cu = b.mul(&sq, x);
    let t = b.tanh(&cu);
    b.mul(x, &t)
}

/// One pre-LN transformer encoder/decoder layer.
fn encoder_layer(b: &mut NetBuilder, x: &TRef, s: &TxSpec, fine: bool, tag: &str) -> TRef {
    let n = x.shape[0];
    let (d, h, seq) = (s.d, s.heads, s.seq);
    let dh = d / h;

    let ln1 = layernorm(b, x, fine, &format!("{tag}.ln1"));
    let q = b.linear(&ln1, d, &format!("{tag}.attn.q"));
    let k = b.linear(&ln1, d, &format!("{tag}.attn.k"));
    let v = b.linear(&ln1, d, &format!("{tag}.attn.v"));
    let qh = b.reshape(&q, &[n, h, seq, dh]);
    let kh = b.reshape(&k, &[n, h, seq, dh]);
    let vh = b.reshape(&v, &[n, h, seq, dh]);
    let scores = b.matmul(&qh, &kh, &[n, h, seq, seq], &format!("{tag}.attn.qk"));
    let scaled = b.scale(&scores);
    let masked = if s.causal {
        // Causal mask add — its own FX node.
        b.scale(&scaled)
    } else {
        scaled
    };
    let probs = softmax(b, &masked, fine, &format!("{tag}.attn.softmax"));
    let probs = b.dropout(&probs, &format!("{tag}.attn.drop"));
    let ctx = b.matmul(&probs, &vh, &[n, h, seq, dh], &format!("{tag}.attn.av"));
    let ctx = b.reshape(&ctx, &[n, seq, d]);
    let proj = b.linear(&ctx, d, &format!("{tag}.attn.proj"));
    let proj = b.dropout(&proj, &format!("{tag}.attn.proj_drop"));
    let x1 = b.add(x, &proj);

    let ln2 = layernorm(b, &x1, fine, &format!("{tag}.ln2"));
    let f1 = b.linear(&ln2, s.ffn, &format!("{tag}.mlp.fc1"));
    let a = gelu(b, &f1, fine);
    let f2 = b.linear(&a, d, &format!("{tag}.mlp.fc2"));
    let f2 = b.dropout(&f2, &format!("{tag}.mlp.drop"));
    b.add(&x1, &f2)
}

/// Stack `layers` encoder layers.
fn encoder(b: &mut NetBuilder, mut x: TRef, s: &TxSpec, fine: bool) -> TRef {
    for l in 0..s.layers {
        x = encoder_layer(b, &x, s, fine, &format!("layers.{l}"));
    }
    x
}

/// ViT-B/16 (Dosovitskiy et al. 2020): 224² images, 16×16 patches,
/// d=768, 12 layers, 12 heads, MLP 3072, 1000-class head.
pub fn vit_b16(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let mut b = NetBuilder::new(format!("vit_bs{n}"));
    let spec = TxSpec {
        d: 768,
        heads: 12,
        ffn: 3072,
        layers: 12,
        seq: 196,
        causal: false,
    };
    let x = b.input("images", &[n, 3, 224, 224]);
    let y = b.input("labels", &[n]);

    // Patch embedding: conv k16 s16 → (N, 768, 14, 14) → (N, 196, 768).
    let pe = b.conv2d(&x, spec.d, 16, 16, 0, "patch_embed");
    let tok = b.reshape(&pe, &[n, spec.seq, spec.d]);
    let tok = b.pos_embed(&tok, "pos_embed");
    let tok = b.dropout(&tok, "embed_drop");

    let enc = encoder(&mut b, tok, &spec, cfg.fine_grained);
    let enc = layernorm(&mut b, &enc, cfg.fine_grained, "final_ln");
    let pooled = b.reduce(&enc, &[n, spec.d], "pool");
    let logits = b.linear(&pooled, 1000, "head");
    b.cross_entropy(&logits, &y);
    b.finish_training(cfg.optim)
}

/// BERT-base (Devlin et al. 2018) with an MLM head: seq 128, d=768,
/// 12 layers, vocab 30522 — the vocab-sized logits are the "huge temporary
/// buffers" the paper calls out for BERT (§V-B).
pub fn bert_base(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let seq = cfg.seq_len.unwrap_or(128);
    let vocab = 30522;
    let mut b = NetBuilder::new(format!("bert_bs{n}"));
    let spec = TxSpec {
        d: 768,
        heads: 12,
        ffn: 3072,
        layers: 12,
        seq,
        causal: false,
    };
    let ids = b.input("input_ids", &[n, seq]);
    let y = b.input("mlm_labels", &[n, seq]);

    let tok = b.embed(&ids, vocab, spec.d, "tok_embed");
    let tok = b.pos_embed(&tok, "pos_embed");
    let tok = layernorm(&mut b, &tok, cfg.fine_grained, "embed_ln");
    let tok = b.dropout(&tok, "embed_drop");

    let enc = encoder(&mut b, tok, &spec, cfg.fine_grained);

    // MLM head: dense + gelu + LN + vocab decoder.
    let h = b.linear(&enc, spec.d, "mlm.transform");
    let h = gelu(&mut b, &h, cfg.fine_grained);
    let h = layernorm(&mut b, &h, cfg.fine_grained, "mlm.ln");
    let logits = b.linear(&h, vocab, "mlm.decoder");
    b.cross_entropy(&logits, &y);
    b.finish_training(cfg.optim)
}

/// GPT2-XL (Radford et al. 2019): 48 layers, d=1600, 25 heads, seq 1024,
/// vocab 50257 — ~1.5 B parameters; the §V-D scalability workload.
pub fn gpt2_xl(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let seq = cfg.seq_len.unwrap_or(1024);
    let vocab = 50257;
    let mut b = NetBuilder::new(format!("gpt2xl_bs{n}"));
    let spec = TxSpec {
        d: 1600,
        heads: 25,
        ffn: 6400,
        layers: 48,
        seq,
        causal: true,
    };
    let ids = b.input("input_ids", &[n, seq]);
    let y = b.input("targets", &[n, seq]);

    let tok = b.embed(&ids, vocab, spec.d, "wte");
    let tok = b.pos_embed(&tok, "wpe");
    let tok = b.dropout(&tok, "embed_drop");

    let enc = encoder(&mut b, tok, &spec, cfg.fine_grained);
    let enc = layernorm(&mut b, &enc, cfg.fine_grained, "final_ln");
    let logits = b.linear(&enc, vocab, "lm_head");
    b.cross_entropy(&logits, &y);
    b.finish_training(cfg.optim)
}

/// Depth-parameterised encoder for the Fig-15 op-count sweep:
/// d=512, 8 heads, FFN 2048, seq 128, `cfg.depth` layers.
pub fn synthetic(cfg: &BuildCfg) -> Graph {
    let n = cfg.batch;
    let seq = cfg.seq_len.unwrap_or(128);
    let mut b = NetBuilder::new(format!("synth_l{}_bs{n}", cfg.depth));
    let spec = TxSpec {
        d: 512,
        heads: 8,
        ffn: 2048,
        layers: cfg.depth,
        seq,
        causal: false,
    };
    let ids = b.input("input_ids", &[n, seq]);
    let y = b.input("targets", &[n, seq]);
    let tok = b.embed(&ids, 8192, spec.d, "tok_embed");
    let tok = b.pos_embed(&tok, "pos_embed");
    let enc = encoder(&mut b, tok, &spec, cfg.fine_grained);
    let enc = layernorm(&mut b, &enc, cfg.fine_grained, "final_ln");
    let logits = b.linear(&enc, 8192, "lm_head");
    b.cross_entropy(&logits, &y);
    b.finish_training(cfg.optim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::models::BuildCfg;

    fn cfg(batch: usize) -> BuildCfg {
        BuildCfg {
            batch,
            ..Default::default()
        }
    }

    #[test]
    fn vit_op_count_in_paper_range() {
        let g = vit_b16(&cfg(1));
        assert!(validate(&g).is_empty());
        // Paper: "around 2000" operators for ViT + Adam (§II).
        assert!(
            (1200..4000).contains(&g.n_ops()),
            "vit has {} ops",
            g.n_ops()
        );
    }

    #[test]
    fn bert_bigger_than_vit() {
        let b = bert_base(&cfg(1));
        let v = vit_b16(&cfg(1));
        assert!(validate(&b).is_empty());
        assert!(b.n_ops() > v.n_ops());
    }

    #[test]
    fn synthetic_scales_with_depth() {
        let small = synthetic(&BuildCfg { depth: 2, ..cfg(1) });
        let big = synthetic(&BuildCfg { depth: 8, ..cfg(1) });
        assert!(validate(&small).is_empty());
        assert!(big.n_ops() > 3 * small.n_ops());
    }

    #[test]
    fn coarse_grained_is_smaller() {
        let fine = vit_b16(&cfg(1));
        let coarse = vit_b16(&BuildCfg {
            fine_grained: false,
            ..cfg(1)
        });
        assert!(coarse.n_ops() < fine.n_ops());
    }

    #[test]
    #[ignore = "large graph; run with --ignored"]
    fn gpt2_xl_is_10k_scale() {
        let g = gpt2_xl(&cfg(1));
        assert!(validate(&g).is_empty());
        // Paper: "more than 10,000 operators" (§II). Our FX-granularity
        // decomposition lands in the same regime.
        assert!(g.n_ops() > 8000, "gpt2-xl has {} ops", g.n_ops());
        // ~1.5B params * 4 bytes ≈ 6 GB of weights.
        assert!(g.persistent_bytes() > 5 * (1 << 30));
    }
}

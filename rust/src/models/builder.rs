//! Layer-level network builder with graph-level autodiff.
//!
//! The paper constructs training graphs from PyTorch programs via torch.FX;
//! we reproduce the same object synthetically (see DESIGN.md
//! §Hardware-Adaptation): model files ([`crate::models`]) describe the
//! forward network with layer calls on [`NetBuilder`], and
//! [`NetBuilder::finish_training`] mirrors it into a backward pass (each
//! backward op consumes the forward activations it needs — this is what
//! creates the long-lived-activation memory profile of training, §III-A)
//! and appends per-parameter weight-update branches shaped like the paper's
//! Fig 6 (Adam: a 3-layer temporary-buffer pattern, hence α = 3 in eq. 6).
//!
//! Tensor sizes are byte-accurate for f32; op granularity matches what FX
//! tracing produces (bias adds, reshapes, dropout masks and gradient
//! accumulations are separate ops), so op counts land in the same range the
//! paper reports (ViT ≈ 2k ops, BERT ≈ 2.7k, GPT2-XL > 10k with Adam).

use crate::graph::{Graph, OpId, OpKind, Phase, TensorClass, TensorId};
use std::collections::HashMap;

/// A tensor handle carrying its logical shape (sizes are derived from it).
#[derive(Clone, Debug)]
pub struct TRef {
    pub id: TensorId,
    pub shape: Vec<usize>,
}

impl TRef {
    pub fn numel(&self) -> u64 {
        self.shape.iter().map(|&d| d as u64).product()
    }
}

/// Which optimizer to expand update branches for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Optim {
    /// Plain SGD: one in-place op per parameter, no extra state.
    Sgd,
    /// Adam: persistent m/v state + the Fig-6 temporary-buffer pattern.
    Adam,
}

/// How gradients flow through a recorded op.
#[derive(Clone, Debug)]
enum BwdRule {
    /// Emit one backward op: inputs = [grad_out] ++ saved, outputs = one
    /// gradient per target.
    Op {
        saved: Vec<TensorId>,
        targets: Vec<GradTarget>,
        /// Extra scratch bytes the backward op materialises (0 = none).
        temp_bytes: u64,
    },
    /// Gradient flows through unchanged (residual add, free reshape):
    /// register grad_out as a contribution to each target, no new op.
    Passthrough { targets: Vec<TensorId> },
    /// No gradient (e.g. pure index ops).
    Stop,
}

#[derive(Clone, Debug)]
struct GradTarget {
    /// The forward tensor this gradient is w.r.t.
    wrt: TensorId,
    /// Gradient size in bytes (= size of `wrt`).
    bytes: u64,
}

#[derive(Clone, Debug)]
struct TapeEntry {
    name: String,
    kind: OpKind,
    /// Primary forward output whose gradient seeds this backward op.
    out: TensorId,
    rule: BwdRule,
}

/// Forward-network builder + training-graph expander.
pub struct NetBuilder {
    pub g: Graph,
    tape: Vec<TapeEntry>,
    /// Parameters requiring gradients, in creation order.
    params: Vec<TensorId>,
    /// Bytes per element (f32 = 4).
    pub elem: u64,
    fresh: usize,
}

impl NetBuilder {
    pub fn new(name: impl Into<String>) -> Self {
        NetBuilder {
            g: Graph::new(name),
            tape: Vec::new(),
            params: Vec::new(),
            elem: 4,
            fresh: 0,
        }
    }

    fn uniq(&mut self, base: &str) -> String {
        self.fresh += 1;
        format!("{base}_{}", self.fresh)
    }

    fn bytes(&self, shape: &[usize]) -> u64 {
        shape.iter().map(|&d| d as u64).product::<u64>() * self.elem
    }

    /// Mini-batch input tensor.
    pub fn input(&mut self, name: &str, shape: &[usize]) -> TRef {
        let id = self
            .g
            .add_input_tensor(name, self.bytes(shape), TensorClass::Input);
        TRef {
            id,
            shape: shape.to_vec(),
        }
    }

    /// Trainable parameter.
    pub fn param(&mut self, name: &str, shape: &[usize]) -> TRef {
        let id = self
            .g
            .add_input_tensor(name, self.bytes(shape), TensorClass::Weight);
        self.params.push(id);
        TRef {
            id,
            shape: shape.to_vec(),
        }
    }

    /// Core primitive: emit a forward op producing one activation of
    /// `out_shape`, and record how to differentiate it.
    #[allow(clippy::too_many_arguments)]
    fn fwd_op(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: &[&TRef],
        out_shape: &[usize],
        saved: Vec<TensorId>,
        grad_wrt: Vec<&TRef>,
        bwd_temp: u64,
    ) -> TRef {
        let nm = self.uniq(name);
        let in_ids: Vec<TensorId> = inputs.iter().map(|t| t.id).collect();
        let ob = self.bytes(out_shape);
        let (_, outs) = self.g.add_op(
            nm.clone(),
            kind,
            Phase::Forward,
            &in_ids,
            &[(&format!("{nm}.out"), ob, TensorClass::Activation)],
        );
        let targets = grad_wrt
            .iter()
            .map(|t| GradTarget {
                wrt: t.id,
                bytes: self.g.tensors[t.id].size,
            })
            .collect();
        self.tape.push(TapeEntry {
            name: nm,
            kind,
            out: outs[0],
            rule: BwdRule::Op {
                saved,
                targets,
                temp_bytes: bwd_temp,
            },
        });
        TRef {
            id: outs[0],
            shape: out_shape.to_vec(),
        }
    }

    // ----- layer vocabulary -------------------------------------------------

    /// Dense / fully-connected: `x[.., in] @ w[in, out] + b[out]`.
    /// Emits matmul + bias-add as two ops (FX granularity).
    pub fn linear(&mut self, x: &TRef, out_features: usize, tag: &str) -> TRef {
        let in_features = *x.shape.last().unwrap();
        let w = self.param(&format!("{tag}.w"), &[in_features, out_features]);
        let b = self.param(&format!("{tag}.b"), &[out_features]);
        let mut oshape = x.shape.clone();
        *oshape.last_mut().unwrap() = out_features;
        let mm = self.fwd_op(
            &format!("{tag}.matmul"),
            OpKind::MatMul,
            &[x, &w],
            &oshape,
            vec![x.id, w.id],
            vec![x, &w],
            0,
        );
        self.fwd_op(
            &format!("{tag}.bias"),
            OpKind::Elementwise,
            &[&mm, &b],
            &oshape,
            vec![],
            vec![&mm, &b],
            0,
        )
    }

    /// 2-D convolution (NCHW). Bias folded into one bias-add op.
    pub fn conv2d(
        &mut self,
        x: &TRef,
        out_c: usize,
        k: usize,
        stride: usize,
        pad: usize,
        tag: &str,
    ) -> TRef {
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let wt = self.param(&format!("{tag}.w"), &[out_c, c, k, k]);
        let b = self.param(&format!("{tag}.b"), &[out_c]);
        let oshape = vec![n, out_c, oh, ow];
        let conv = self.fwd_op(
            &format!("{tag}.conv"),
            OpKind::Conv,
            &[x, &wt],
            &oshape,
            vec![x.id, wt.id],
            vec![x, &wt],
            // conv backward uses an im2col-style scratch.
            self.bytes(&[n, c * k * k, oh * ow]) / 4,
        );
        self.fwd_op(
            &format!("{tag}.bias"),
            OpKind::Elementwise,
            &[&conv, &b],
            &oshape,
            vec![],
            vec![&conv, &b],
            0,
        )
    }

    /// Depthwise 2-D convolution (groups = channels).
    pub fn dwconv2d(&mut self, x: &TRef, k: usize, stride: usize, pad: usize, tag: &str) -> TRef {
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let oh = (h + 2 * pad - k) / stride + 1;
        let ow = (w + 2 * pad - k) / stride + 1;
        let wt = self.param(&format!("{tag}.w"), &[c, 1, k, k]);
        let oshape = vec![n, c, oh, ow];
        self.fwd_op(
            &format!("{tag}.dwconv"),
            OpKind::Conv,
            &[x, &wt],
            &oshape,
            vec![x.id, wt.id],
            vec![x, &wt],
            0,
        )
    }

    /// BatchNorm: emits the normalised output plus small saved statistics.
    pub fn batchnorm(&mut self, x: &TRef, tag: &str) -> TRef {
        let c = x.shape[1];
        let gamma = self.param(&format!("{tag}.gamma"), &[c]);
        let beta = self.param(&format!("{tag}.beta"), &[c]);
        // Saved mean/invstd are (C)-sized activations kept for backward.
        let nm = self.uniq(&format!("{tag}.bn"));
        let stats_b = self.bytes(&[2 * c]);
        let ob = self.bytes(&x.shape);
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::BatchNorm,
            Phase::Forward,
            &[x.id, gamma.id, beta.id],
            &[
                (&format!("{nm}.out"), ob, TensorClass::Activation),
                (&format!("{nm}.stats"), stats_b, TensorClass::Activation),
            ],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::BatchNorm,
            out: outs[0],
            rule: BwdRule::Op {
                saved: vec![x.id, gamma.id, outs[1]],
                targets: vec![
                    GradTarget { wrt: x.id, bytes: self.g.tensors[x.id].size },
                    GradTarget { wrt: gamma.id, bytes: self.g.tensors[gamma.id].size },
                    GradTarget { wrt: beta.id, bytes: self.g.tensors[beta.id].size },
                ],
                temp_bytes: 0,
            },
        });
        TRef { id: outs[0], shape: x.shape.clone() }
    }

    /// LayerNorm over the last dimension (transformers).
    pub fn layernorm(&mut self, x: &TRef, tag: &str) -> TRef {
        let d = *x.shape.last().unwrap();
        let gamma = self.param(&format!("{tag}.gamma"), &[d]);
        let beta = self.param(&format!("{tag}.beta"), &[d]);
        let nm = self.uniq(&format!("{tag}.ln"));
        let rows: usize = x.shape[..x.shape.len() - 1].iter().product();
        let stats_b = self.bytes(&[2 * rows]);
        let ob = self.bytes(&x.shape);
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::LayerNorm,
            Phase::Forward,
            &[x.id, gamma.id, beta.id],
            &[
                (&format!("{nm}.out"), ob, TensorClass::Activation),
                (&format!("{nm}.stats"), stats_b, TensorClass::Activation),
            ],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::LayerNorm,
            out: outs[0],
            rule: BwdRule::Op {
                saved: vec![x.id, gamma.id, outs[1]],
                targets: vec![
                    GradTarget { wrt: x.id, bytes: self.g.tensors[x.id].size },
                    GradTarget { wrt: gamma.id, bytes: self.g.tensors[gamma.id].size },
                    GradTarget { wrt: beta.id, bytes: self.g.tensors[beta.id].size },
                ],
                temp_bytes: 0,
            },
        });
        TRef { id: outs[0], shape: x.shape.clone() }
    }

    /// Unary activation whose backward needs the *input* (relu, gelu, ...).
    pub fn act(&mut self, x: &TRef, kind_name: &str) -> TRef {
        let shape = x.shape.clone();
        self.fwd_op(
            kind_name,
            OpKind::Activation,
            &[x],
            &shape,
            vec![x.id],
            vec![x],
            0,
        )
    }

    pub fn relu(&mut self, x: &TRef) -> TRef {
        self.act(x, "relu")
    }

    pub fn gelu(&mut self, x: &TRef) -> TRef {
        self.act(x, "gelu")
    }

    pub fn swish(&mut self, x: &TRef) -> TRef {
        self.act(x, "swish")
    }

    pub fn sigmoid(&mut self, x: &TRef) -> TRef {
        self.act(x, "sigmoid")
    }

    pub fn tanh(&mut self, x: &TRef) -> TRef {
        self.act(x, "tanh")
    }

    /// Max/avg pool.
    pub fn pool2d(&mut self, x: &TRef, k: usize, stride: usize, tag: &str) -> TRef {
        let (n, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
        let oh = (h - k) / stride + 1;
        let ow = (w - k) / stride + 1;
        let oshape = vec![n, c, oh, ow];
        self.fwd_op(tag, OpKind::Pool, &[x], &oshape, vec![x.id], vec![x], 0)
    }

    /// Global average pool to (N, C).
    pub fn gap(&mut self, x: &TRef) -> TRef {
        let (n, c) = (x.shape[0], x.shape[1]);
        self.fwd_op("gap", OpKind::Pool, &[x], &[n, c], vec![x.id], vec![x], 0)
    }

    /// Residual / elementwise add. Gradient passes through to both sides.
    pub fn add(&mut self, a: &TRef, b: &TRef) -> TRef {
        assert_eq!(self.bytes(&a.shape), self.bytes(&b.shape), "add shape mismatch");
        let nm = self.uniq("add");
        let ob = self.bytes(&a.shape);
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::Elementwise,
            Phase::Forward,
            &[a.id, b.id],
            &[(&format!("{nm}.out"), ob, TensorClass::Activation)],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::Elementwise,
            out: outs[0],
            rule: BwdRule::Passthrough {
                targets: vec![a.id, b.id],
            },
        });
        TRef { id: outs[0], shape: a.shape.clone() }
    }

    /// Elementwise multiply (SE gates, masks). Backward needs both inputs.
    pub fn mul(&mut self, a: &TRef, b: &TRef) -> TRef {
        let shape = a.shape.clone();
        self.fwd_op(
            "mul",
            OpKind::Elementwise,
            &[a, b],
            &shape,
            vec![a.id, b.id],
            vec![a, b],
            0,
        )
    }

    /// Scale by a constant (1/sqrt(d) in attention).
    pub fn scale(&mut self, x: &TRef) -> TRef {
        let shape = x.shape.clone();
        self.fwd_op("scale", OpKind::Elementwise, &[x], &shape, vec![], vec![x], 0)
    }

    /// Batched matmul for attention: (..., a, b) @ (..., b, c).
    pub fn matmul(&mut self, a: &TRef, b: &TRef, out_shape: &[usize], tag: &str) -> TRef {
        self.fwd_op(
            tag,
            OpKind::MatMul,
            &[a, b],
            out_shape,
            vec![a.id, b.id],
            vec![a, b],
            0,
        )
    }

    /// Reduction (mean/max/sum) to `out_shape`; backward needs the input.
    pub fn reduce(&mut self, x: &TRef, out_shape: &[usize], tag: &str) -> TRef {
        self.fwd_op(tag, OpKind::Reduce, &[x], out_shape, vec![x.id], vec![x], 0)
    }

    /// Broadcast binary elementwise op (`a ⊙ broadcast(b)`), output shaped
    /// like `a`; backward needs both operands (SE gating, mean-subtract,
    /// variance-divide in fine-grained layernorm...).
    pub fn bcast(&mut self, a: &TRef, b: &TRef, tag: &str) -> TRef {
        let shape = a.shape.clone();
        self.fwd_op(
            tag,
            OpKind::Elementwise,
            &[a, b],
            &shape,
            vec![a.id, b.id],
            vec![a, b],
            0,
        )
    }

    /// Softmax over the last dim; backward needs the output.
    pub fn softmax(&mut self, x: &TRef) -> TRef {
        let shape = x.shape.clone();
        let nm = self.uniq("softmax");
        let ob = self.bytes(&shape);
        let in_ids = vec![x.id];
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::Softmax,
            Phase::Forward,
            &in_ids,
            &[(&format!("{nm}.out"), ob, TensorClass::Activation)],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::Softmax,
            out: outs[0],
            rule: BwdRule::Op {
                saved: vec![outs[0]], // softmax bwd uses its own output
                targets: vec![GradTarget { wrt: x.id, bytes: self.g.tensors[x.id].size }],
                temp_bytes: 0,
            },
        });
        TRef { id: outs[0], shape }
    }

    /// Dropout: emits a mask activation kept until backward.
    pub fn dropout(&mut self, x: &TRef, tag: &str) -> TRef {
        let nm = self.uniq(tag);
        let ob = self.bytes(&x.shape);
        // Mask is one byte per element.
        let mask_b = x.numel();
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::Elementwise,
            Phase::Forward,
            &[x.id],
            &[
                (&format!("{nm}.out"), ob, TensorClass::Activation),
                (&format!("{nm}.mask"), mask_b, TensorClass::Activation),
            ],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::Elementwise,
            out: outs[0],
            rule: BwdRule::Op {
                saved: vec![outs[1]],
                targets: vec![GradTarget { wrt: x.id, bytes: self.g.tensors[x.id].size }],
                temp_bytes: 0,
            },
        });
        TRef { id: outs[0], shape: x.shape.clone() }
    }

    /// Reshape/view — a real FX node, but gradient passes through for free.
    pub fn reshape(&mut self, x: &TRef, new_shape: &[usize]) -> TRef {
        assert_eq!(self.bytes(&x.shape), self.bytes(new_shape), "reshape numel mismatch");
        let nm = self.uniq("reshape");
        let ob = self.bytes(new_shape);
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::Reshape,
            Phase::Forward,
            &[x.id],
            &[(&format!("{nm}.out"), ob, TensorClass::Activation)],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::Reshape,
            out: outs[0],
            rule: BwdRule::Passthrough {
                targets: vec![x.id],
            },
        });
        TRef { id: outs[0], shape: new_shape.to_vec() }
    }

    pub fn flatten(&mut self, x: &TRef) -> TRef {
        let n = x.shape[0];
        let rest: usize = x.shape[1..].iter().product();
        self.reshape(x, &[n, rest])
    }

    /// Token embedding lookup: ids (N, S) -> (N, S, D). Gradient only to
    /// the embedding table.
    pub fn embed(&mut self, ids: &TRef, vocab: usize, dim: usize, tag: &str) -> TRef {
        let table = self.param(&format!("{tag}.table"), &[vocab, dim]);
        let mut oshape = ids.shape.clone();
        oshape.push(dim);
        let nm = self.uniq(tag);
        let ob = self.bytes(&oshape);
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::Embed,
            Phase::Forward,
            &[ids.id, table.id],
            &[(&format!("{nm}.out"), ob, TensorClass::Activation)],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::Embed,
            out: outs[0],
            rule: BwdRule::Op {
                saved: vec![ids.id],
                targets: vec![GradTarget { wrt: table.id, bytes: self.g.tensors[table.id].size }],
                temp_bytes: 0,
            },
        });
        TRef { id: outs[0], shape: oshape }
    }

    /// Positional-embedding add: x + pos_table (broadcast over batch).
    pub fn pos_embed(&mut self, x: &TRef, tag: &str) -> TRef {
        let table = self.param(&format!("{tag}.pos"), &x.shape[1..].to_vec());
        let shape = x.shape.clone();
        self.fwd_op(
            tag,
            OpKind::Elementwise,
            &[x, &table],
            &shape,
            vec![],
            vec![x, &table],
            0,
        )
    }

    /// Cross-entropy loss against integer targets.
    pub fn cross_entropy(&mut self, logits: &TRef, targets: &TRef) -> TRef {
        let nm = self.uniq("xent");
        let (_, outs) = self.g.add_op(
            nm.clone(),
            OpKind::Loss,
            Phase::Loss,
            &[logits.id, targets.id],
            &[(&format!("{nm}.loss"), self.elem, TensorClass::TempBuffer)],
        );
        self.tape.push(TapeEntry {
            name: nm,
            kind: OpKind::Loss,
            out: outs[0],
            rule: BwdRule::Op {
                saved: vec![logits.id, targets.id],
                targets: vec![GradTarget { wrt: logits.id, bytes: self.g.tensors[logits.id].size }],
                temp_bytes: 0,
            },
        });
        self.g.mark_output(outs[0]);
        TRef { id: outs[0], shape: vec![1] }
    }

    // ----- training expansion ----------------------------------------------

    /// Generate the backward pass and weight-update branches, consuming the
    /// builder and returning the complete training graph.
    ///
    /// The backward pass walks the tape in reverse: each entry's output
    /// gradient (accumulated across consumers with explicit `GradAcc` ops —
    /// FX shows these too) feeds a backward op that consumes the saved
    /// forward tensors. Weight updates follow `optim`:
    ///
    /// * SGD — one `OptimStep` op per parameter;
    /// * Adam — per parameter: persistent `m`/`v` state plus the paper's
    ///   Fig-6 pattern (update-m, update-v, normalise, step — three
    ///   w-sized temporaries live at once, matching α = 3 in eq. 6).
    pub fn finish_training(mut self, optim: Optim) -> Graph {
        // Contributions per forward tensor.
        let mut contrib: HashMap<TensorId, Vec<TensorId>> = HashMap::new();
        // Loss entries seed their own gradient implicitly (dL/dL = 1).
        let tape = std::mem::take(&mut self.tape);

        // Pre-scan: loss entries are roots.
        for entry in tape.iter().rev() {
            let is_loss = entry.kind == OpKind::Loss;
            // Gather the accumulated gradient of this op's output.
            let grads = contrib.remove(&entry.out).unwrap_or_default();
            let grad_out: Option<TensorId> = if is_loss {
                None // loss grad is the scalar 1, not materialised
            } else if grads.is_empty() {
                continue; // output unused: no backward needed
            } else if grads.len() == 1 {
                Some(grads[0])
            } else {
                // Explicit gradient accumulation op.
                let nm = format!("{}.gradacc", entry.name);
                let b = self.g.tensors[grads[0]].size;
                let (_, outs) = self.g.add_op(
                    nm.clone(),
                    OpKind::GradAcc,
                    Phase::Backward,
                    &grads,
                    &[(&format!("{nm}.out"), b, TensorClass::Gradient)],
                );
                Some(outs[0])
            };

            match &entry.rule {
                BwdRule::Stop => {}
                BwdRule::Passthrough { targets } => {
                    let go = grad_out.expect("passthrough on loss is impossible");
                    for &t in targets {
                        contrib.entry(t).or_default().push(go);
                    }
                }
                BwdRule::Op {
                    saved,
                    targets,
                    temp_bytes,
                } => {
                    let nm = format!("{}.bwd", entry.name);
                    let mut inputs: Vec<TensorId> = Vec::new();
                    if let Some(go) = grad_out {
                        inputs.push(go);
                    }
                    inputs.extend(saved.iter().copied());
                    let mut outs_spec: Vec<(String, u64, TensorClass)> = targets
                        .iter()
                        .enumerate()
                        .map(|(i, t)| (format!("{nm}.d{i}"), t.bytes, TensorClass::Gradient))
                        .collect();
                    if *temp_bytes > 0 {
                        outs_spec.push((format!("{nm}.scratch"), *temp_bytes, TensorClass::TempBuffer));
                    }
                    let outs_ref: Vec<(&str, u64, TensorClass)> = outs_spec
                        .iter()
                        .map(|(n, s, c)| (n.as_str(), *s, *c))
                        .collect();
                    let (_, produced) = self.g.add_op(
                        nm.clone(),
                        bwd_kind(entry.kind),
                        Phase::Backward,
                        &inputs,
                        &outs_ref,
                    );
                    for (i, t) in targets.iter().enumerate() {
                        contrib.entry(t.wrt).or_default().push(produced[i]);
                    }
                }
            }
        }

        // Weight updates.
        let params = std::mem::take(&mut self.params);
        for (k, p) in params.into_iter().enumerate() {
            let grads = contrib.remove(&p).unwrap_or_default();
            if grads.is_empty() {
                continue; // parameter unused
            }
            let dw = if grads.len() == 1 {
                grads[0]
            } else {
                let nm = format!("p{k}.gradacc");
                let b = self.g.tensors[p].size;
                let (_, outs) = self.g.add_op(
                    nm.clone(),
                    OpKind::GradAcc,
                    Phase::Backward,
                    &grads,
                    &[(&format!("{nm}.out"), b, TensorClass::Gradient)],
                );
                outs[0]
            };
            let wsize = self.g.tensors[p].size;
            match optim {
                Optim::Sgd => {
                    let (_, out) = self.g.add_op(
                        format!("p{k}.sgd_step"),
                        OpKind::OptimStep,
                        Phase::Update,
                        &[dw, p],
                        &[(&format!("p{k}.w_new"), wsize, TensorClass::TempBuffer)],
                    );
                    self.g.mark_output(out[0]);
                }
                Optim::Adam => {
                    // Fig-6 structure: the update branch materialises a
                    // chain of w-sized temporaries of which at most three
                    // overlap in lifetime — the "3 layers" that justify
                    // α = 3 in eq. (6).
                    let m = self
                        .g
                        .add_input_tensor(format!("p{k}.adam_m"), wsize, TensorClass::OptState);
                    let v = self
                        .g
                        .add_input_tensor(format!("p{k}.adam_v"), wsize, TensorClass::OptState);
                    let (_, m_new) = self.g.add_op(
                        format!("p{k}.adam_m_upd"),
                        OpKind::Elementwise,
                        Phase::Update,
                        &[dw, m],
                        &[(&format!("p{k}.m_new"), wsize, TensorClass::TempBuffer)],
                    );
                    let (_, g_sq) = self.g.add_op(
                        format!("p{k}.adam_gsq"),
                        OpKind::Elementwise,
                        Phase::Update,
                        &[dw],
                        &[(&format!("p{k}.g_sq"), wsize, TensorClass::TempBuffer)],
                    );
                    let (_, v_new) = self.g.add_op(
                        format!("p{k}.adam_v_upd"),
                        OpKind::Elementwise,
                        Phase::Update,
                        &[g_sq[0], v],
                        &[(&format!("p{k}.v_new"), wsize, TensorClass::TempBuffer)],
                    );
                    let (_, denom) = self.g.add_op(
                        format!("p{k}.adam_sqrt"),
                        OpKind::Elementwise,
                        Phase::Update,
                        &[v_new[0]],
                        &[(&format!("p{k}.denom"), wsize, TensorClass::TempBuffer)],
                    );
                    let (_, upd) = self.g.add_op(
                        format!("p{k}.adam_div"),
                        OpKind::Elementwise,
                        Phase::Update,
                        &[m_new[0], denom[0]],
                        &[(&format!("p{k}.upd"), wsize, TensorClass::TempBuffer)],
                    );
                    let (_, out) = self.g.add_op(
                        format!("p{k}.adam_step"),
                        OpKind::OptimStep,
                        Phase::Update,
                        &[upd[0], p],
                        &[(&format!("p{k}.w_new"), wsize, TensorClass::TempBuffer)],
                    );
                    self.g.mark_output(out[0]);
                }
            }
        }
        self.g
    }

    /// Inference-only finish (no backward): used by a few unit tests.
    pub fn finish_inference(self) -> Graph {
        self.g
    }
}

/// Backward op category for a forward category.
fn bwd_kind(k: OpKind) -> OpKind {
    match k {
        OpKind::Loss => OpKind::Loss,
        OpKind::Conv => OpKind::Conv,
        OpKind::MatMul => OpKind::MatMul,
        other => other,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::graph::{Phase, TensorClass};

    fn mlp(optim: Optim) -> Graph {
        let mut b = NetBuilder::new("mlp");
        let x = b.input("x", &[4, 16]);
        let y = b.input("y", &[4]);
        let h = b.linear(&x, 32, "fc1");
        let h = b.relu(&h);
        let h = b.linear(&h, 8, "fc2");
        b.cross_entropy(&h, &y);
        b.finish_training(optim)
    }

    #[test]
    fn mlp_training_graph_valid() {
        let g = mlp(Optim::Adam);
        assert!(validate(&g).is_empty(), "{:?}", validate(&g));
        // fwd: 2 matmul + 2 bias + relu + loss = 6
        assert_eq!(g.ops_in_phase(Phase::Forward).count(), 5);
        assert_eq!(g.ops_in_phase(Phase::Loss).count(), 1);
        assert!(g.ops_in_phase(Phase::Backward).count() >= 5);
        // 4 params * 6 adam ops (Fig-6 expansion)
        assert_eq!(g.ops_in_phase(Phase::Update).count(), 24);
    }

    #[test]
    fn sgd_has_one_update_per_param() {
        let g = mlp(Optim::Sgd);
        assert_eq!(g.ops_in_phase(Phase::Update).count(), 4);
        assert_eq!(
            g.tensors.iter().filter(|t| t.class == TensorClass::OptState).count(),
            0
        );
    }

    #[test]
    fn adam_has_mv_state() {
        let g = mlp(Optim::Adam);
        assert_eq!(
            g.tensors.iter().filter(|t| t.class == TensorClass::OptState).count(),
            8
        );
    }

    #[test]
    fn backward_consumes_activations() {
        let g = mlp(Optim::Adam);
        // Some forward activation must be consumed by a backward op —
        // that is the defining memory property of training (§III-A).
        let consumed_in_bwd = g.tensors.iter().any(|t| {
            t.class == TensorClass::Activation
                && t.producer.map(|p| g.ops[p].phase == Phase::Forward).unwrap_or(false)
                && t.consumers.iter().any(|&c| g.ops[c].phase == Phase::Backward)
        });
        assert!(consumed_in_bwd);
    }

    #[test]
    fn residual_creates_gradacc() {
        let mut b = NetBuilder::new("res");
        let x = b.input("x", &[2, 8]);
        let h1 = b.linear(&x, 8, "f1");
        let h2 = b.add(&h1, &x); // x used twice -> grad accumulation for x's consumers
        let h3 = b.linear(&h2, 8, "f2");
        let h4 = b.add(&h3, &h2); // h2 used twice
        let y = b.input("y", &[2]);
        b.cross_entropy(&h4, &y);
        let g = b.finish_training(Optim::Sgd);
        assert!(validate(&g).is_empty());
        assert!(g.ops.iter().any(|o| o.kind == OpKind::GradAcc));
    }

    #[test]
    fn conv_shapes() {
        let mut b = NetBuilder::new("c");
        let x = b.input("x", &[1, 3, 32, 32]);
        let c = b.conv2d(&x, 8, 3, 1, 1, "conv1");
        assert_eq!(c.shape, vec![1, 8, 32, 32]);
        let p = b.pool2d(&c, 2, 2, "pool");
        assert_eq!(p.shape, vec![1, 8, 16, 16]);
        let c2 = b.conv2d(&p, 4, 3, 2, 1, "conv2");
        assert_eq!(c2.shape, vec![1, 4, 8, 8]);
    }

    #[test]
    fn dropout_mask_lives_to_backward() {
        let mut b = NetBuilder::new("d");
        let x = b.input("x", &[2, 8]);
        let h = b.linear(&x, 8, "f");
        let h = b.dropout(&h, "drop");
        let y = b.input("y", &[2]);
        b.cross_entropy(&h, &y);
        let g = b.finish_training(Optim::Sgd);
        let mask = g.tensors.iter().find(|t| t.name.contains("mask")).unwrap();
        assert!(mask
            .consumers
            .iter()
            .any(|&c| g.ops[c].phase == Phase::Backward));
    }
}

//! Graphviz DOT export for debugging and the docs.
//!
//! `roam export-dot --model vit | dot -Tpng > vit.png` renders the training
//! graph with phases colour-coded and tensor sizes on the edges.

use super::{Graph, Phase};
use crate::util::human_bytes;
use std::fmt::Write as _;

/// Render the graph as a DOT digraph string.
pub fn to_dot(g: &Graph) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "digraph \"{}\" {{", g.name);
    let _ = writeln!(s, "  rankdir=TB; node [shape=box, fontsize=10];");
    for op in &g.ops {
        let color = match op.phase {
            Phase::Forward => "lightblue",
            Phase::Loss => "gold",
            Phase::Backward => "lightpink",
            Phase::Update => "lightgreen",
        };
        let _ = writeln!(
            s,
            "  op{} [label=\"{}\", style=filled, fillcolor={}];",
            op.id, op.name, color
        );
    }
    for t in &g.tensors {
        if let Some(p) = t.producer {
            for &c in &t.consumers {
                let _ = writeln!(
                    s,
                    "  op{} -> op{} [label=\"{} ({})\", fontsize=8];",
                    p,
                    c,
                    t.name,
                    human_bytes(t.size)
                );
            }
        }
    }
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, TensorClass};

    #[test]
    fn renders_nodes_and_edges() {
        let mut g = Graph::new("d");
        let x = g.add_input_tensor("x", 1024, TensorClass::Input);
        let (_, t) = g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("t", 2048, TensorClass::Activation)]);
        g.add_op("b", OpKind::Other, Phase::Backward, &[t[0]],
            &[("u", 1, TensorClass::Gradient)]);
        let dot = to_dot(&g);
        assert!(dot.contains("digraph"));
        assert!(dot.contains("op0 -> op1"));
        assert!(dot.contains("2.00 KiB"));
        assert!(dot.contains("lightpink")); // backward colouring
    }
}

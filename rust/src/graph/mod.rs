//! Computation-graph substrate.
//!
//! ROAM models a DNN training program as a DAG `G = (V, E)` where vertices
//! are operators and edges are tensors (§III-B of the paper). This module
//! owns the data structure every other layer consumes: the model builders
//! emit it, the HLO parser produces it from real JAX artifacts, and the
//! schedulers / layout solvers / planner all read it.
//!
//! Memory semantics: a tensor becomes **live** when its producer executes
//! and **dies** after its last consumer executes (tensors without consumers
//! die immediately after production, except graph *outputs* which live to
//! the end). *Persistent* tensors (weights, optimizer moments) occupy a
//! constant resident set that planning can't move; they are accounted
//! separately so the planner optimises only the dynamic arena — exactly the
//! part PyTorch's caching allocator manages.

pub mod dot;
pub mod liveness;
pub mod random;
pub mod reach;
pub mod topo;
pub mod validate;

pub use liveness::{lifetimes, lifetimes_with_horizon, Lifetime};
pub use reach::Reachability;

/// Operator index into [`Graph::ops`].
pub type OpId = usize;
/// Tensor index into [`Graph::tensors`].
pub type TensorId = usize;

/// Which training stage an operator belongs to (§III-A).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum Phase {
    /// Forward propagation.
    Forward,
    /// Loss computation (the fwd/bwd boundary; peak memory usually here).
    Loss,
    /// Backward propagation.
    Backward,
    /// Weight update (optimizer step) — the flexibly schedulable branch.
    Update,
}

/// Coarse operator category. The planner is category-agnostic (it only
/// reads tensor sizes), but categories drive the synthetic-graph builders,
/// DOT rendering and a few heuristic baselines (e.g. LESCEA tie-breaks).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum OpKind {
    Conv,
    MatMul,
    BatchNorm,
    LayerNorm,
    Activation, // relu/gelu/swish...
    Softmax,
    Pool,
    Elementwise, // add/mul/scale...
    Reshape,
    Reduce,
    Embed,
    Loss,
    GradAcc,
    OptimStep,
    Input,
    /// Device→host eviction copy inserted by the [`crate::swap`] rewriter:
    /// consumes the evicted tensor, emits a 1-byte host handle.
    SwapOut,
    /// Host→device fetch of a previously swapped tensor: consumes the
    /// handle, re-materialises the tensor for its backward consumers.
    SwapIn,
    /// In-place shrink inserted by the [`crate::compress`] rewriter:
    /// consumes the evicted tensor, emits the compressed representation
    /// (codec-ratio × original bytes) that stays resident on device.
    Compress,
    /// Inverse of `Compress`: consumes the compressed representation and
    /// re-materialises the full tensor for its backward consumers.
    Decompress,
    Other,
}

/// How a tensor behaves over a training step — drives the shared-tensor
/// rules (§IV-B) and the weight-update scheduler (§IV-A).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub enum TensorClass {
    /// Created in forward, preserved until its gradient consumer (§III-A).
    Activation,
    /// Produced in backward for a parameter; consumed by the update branch.
    Gradient,
    /// Short-lived scratch (optimizer temporaries, softmax scratch, ...).
    TempBuffer,
    /// Parameter — persistent across steps, not placed in the dynamic arena.
    Weight,
    /// Optimizer state (Adam m/v) — persistent like weights.
    OptState,
    /// Mini-batch input — live from step start.
    Input,
}

impl TensorClass {
    /// Persistent tensors live across steps and are excluded from the
    /// dynamically planned arena.
    pub fn is_persistent(self) -> bool {
        matches!(self, TensorClass::Weight | TensorClass::OptState)
    }
}

/// A tensor (edge) in the graph.
#[derive(Clone, Debug)]
pub struct Tensor {
    pub id: TensorId,
    pub name: String,
    /// Size in bytes (`size_e` in the paper).
    pub size: u64,
    /// Producing operator; `None` for graph inputs / parameters.
    pub producer: Option<OpId>,
    /// Consuming operators (may be empty for outputs / dead values).
    pub consumers: Vec<OpId>,
    pub class: TensorClass,
    /// Graph output: kept live until the end of the step.
    pub is_output: bool,
}

/// An operator (vertex) in the graph.
#[derive(Clone, Debug)]
pub struct Op {
    pub id: OpId,
    pub name: String,
    pub kind: OpKind,
    pub phase: Phase,
    pub inputs: Vec<TensorId>,
    pub outputs: Vec<TensorId>,
}

/// The computation graph: operators + tensors + derived op-level adjacency.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub name: String,
    pub ops: Vec<Op>,
    pub tensors: Vec<Tensor>,
}

impl Graph {
    /// Empty graph with a name.
    pub fn new(name: impl Into<String>) -> Self {
        Graph {
            name: name.into(),
            ops: Vec::new(),
            tensors: Vec::new(),
        }
    }

    pub fn n_ops(&self) -> usize {
        self.ops.len()
    }

    pub fn n_tensors(&self) -> usize {
        self.tensors.len()
    }

    /// Add a graph-input tensor (no producer): weights, inputs, opt state.
    pub fn add_input_tensor(
        &mut self,
        name: impl Into<String>,
        size: u64,
        class: TensorClass,
    ) -> TensorId {
        let id = self.tensors.len();
        self.tensors.push(Tensor {
            id,
            name: name.into(),
            size,
            producer: None,
            consumers: Vec::new(),
            class,
            is_output: false,
        });
        id
    }

    /// Add an operator consuming `inputs`; `outputs` describes the tensors
    /// it produces as `(name, size, class)` triples. Returns the op id and
    /// the ids of the produced tensors.
    pub fn add_op(
        &mut self,
        name: impl Into<String>,
        kind: OpKind,
        phase: Phase,
        inputs: &[TensorId],
        outputs: &[(&str, u64, TensorClass)],
    ) -> (OpId, Vec<TensorId>) {
        let op_id = self.ops.len();
        let mut out_ids = Vec::with_capacity(outputs.len());
        for (oname, size, class) in outputs {
            let tid = self.tensors.len();
            self.tensors.push(Tensor {
                id: tid,
                name: oname.to_string(),
                size: *size,
                producer: Some(op_id),
                consumers: Vec::new(),
                class: *class,
                is_output: false,
            });
            out_ids.push(tid);
        }
        for &tid in inputs {
            self.tensors[tid].consumers.push(op_id);
        }
        self.ops.push(Op {
            id: op_id,
            name: name.into(),
            kind,
            phase,
            inputs: inputs.to_vec(),
            outputs: out_ids.clone(),
        });
        (op_id, out_ids)
    }

    /// Mark a tensor as a graph output (pinned live to the end of step).
    pub fn mark_output(&mut self, t: TensorId) {
        self.tensors[t].is_output = true;
    }

    /// Replace every occurrence of `old` in `op`'s input list with `new`,
    /// keeping both tensors' consumer lists count-consistent (the invariant
    /// [`validate::validate`] checks). Returns the number of occurrences
    /// replaced. This is the primitive the recompute rewriter uses to
    /// retarget backward consumers from an evicted tensor to its clone.
    pub fn replace_input(&mut self, op: OpId, old: TensorId, new: TensorId) -> usize {
        if old == new {
            return 0;
        }
        let mut replaced = 0usize;
        for slot in self.ops[op].inputs.iter_mut() {
            if *slot == old {
                *slot = new;
                replaced += 1;
            }
        }
        if replaced > 0 {
            let mut to_remove = replaced;
            self.tensors[old].consumers.retain(|&c| {
                if c == op && to_remove > 0 {
                    to_remove -= 1;
                    false
                } else {
                    true
                }
            });
            for _ in 0..replaced {
                self.tensors[new].consumers.push(op);
            }
        }
        replaced
    }

    /// Add `t` as an extra (control) input of `op`, reusing an existing
    /// tensor (no extra bytes). The caller is responsible for acyclicity —
    /// the recompute rewriter proves it via a reachability check before
    /// calling. (The weight-update scheduler's control edges use a
    /// different encoding: fresh 1-byte tensors, see
    /// [`crate::sched::weight_update::apply_control_edges`].)
    pub fn add_control_input(&mut self, op: OpId, t: TensorId) {
        self.ops[op].inputs.push(t);
        self.tensors[t].consumers.push(op);
    }

    /// Operator-level predecessor ids (dedup'd, order of first appearance).
    pub fn preds(&self, v: OpId) -> Vec<OpId> {
        let mut out = Vec::new();
        for &t in &self.ops[v].inputs {
            if let Some(p) = self.tensors[t].producer {
                if !out.contains(&p) {
                    out.push(p);
                }
            }
        }
        out
    }

    /// Operator-level successor ids (dedup'd).
    pub fn succs(&self, v: OpId) -> Vec<OpId> {
        let mut out = Vec::new();
        for &t in &self.ops[v].outputs {
            for &c in &self.tensors[t].consumers {
                if !out.contains(&c) {
                    out.push(c);
                }
            }
        }
        out
    }

    /// Adjacency lists for all ops at once (cheaper than per-op calls in
    /// the hot analyses). Returns `(preds, succs)`.
    pub fn adjacency(&self) -> (Vec<Vec<OpId>>, Vec<Vec<OpId>>) {
        let n = self.ops.len();
        let mut preds = vec![Vec::new(); n];
        let mut succs = vec![Vec::new(); n];
        for op in &self.ops {
            for &t in &op.inputs {
                if let Some(p) = self.tensors[t].producer {
                    if !preds[op.id].contains(&p) {
                        preds[op.id].push(p);
                        succs[p].push(op.id);
                    }
                }
            }
        }
        (preds, succs)
    }

    /// Sum of persistent tensor sizes (weights + optimizer state) — the
    /// constant resident set the dynamic arena sits on top of.
    pub fn persistent_bytes(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| t.class.is_persistent())
            .map(|t| t.size)
            .sum()
    }

    /// Sum of *dynamic* (non-persistent) tensor sizes.
    pub fn dynamic_bytes(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| !t.class.is_persistent())
            .map(|t| t.size)
            .sum()
    }

    /// Sum of activation sizes — `esti_pm` of eq. (4).
    pub fn activation_bytes(&self) -> u64 {
        self.tensors
            .iter()
            .filter(|t| t.class == TensorClass::Activation)
            .map(|t| t.size)
            .sum()
    }

    /// Ops in a given phase.
    pub fn ops_in_phase(&self, phase: Phase) -> impl Iterator<Item = &Op> {
        self.ops.iter().filter(move |o| o.phase == phase)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// a -> t1 -> b -> t2 -> c ; plus weight w consumed by a.
    fn tiny() -> Graph {
        let mut g = Graph::new("tiny");
        let w = g.add_input_tensor("w", 100, TensorClass::Weight);
        let x = g.add_input_tensor("x", 10, TensorClass::Input);
        let (_a, t1) = g.add_op(
            "a",
            OpKind::MatMul,
            Phase::Forward,
            &[w, x],
            &[("t1", 20, TensorClass::Activation)],
        );
        let (_b, t2) = g.add_op(
            "b",
            OpKind::Activation,
            Phase::Forward,
            &[t1[0]],
            &[("t2", 20, TensorClass::Activation)],
        );
        let (_c, t3) = g.add_op(
            "c",
            OpKind::Loss,
            Phase::Loss,
            &[t2[0]],
            &[("loss", 4, TensorClass::TempBuffer)],
        );
        g.mark_output(t3[0]);
        g
    }

    #[test]
    fn build_and_adjacency() {
        let g = tiny();
        assert_eq!(g.n_ops(), 3);
        assert_eq!(g.n_tensors(), 5);
        assert_eq!(g.preds(1), vec![0]);
        assert_eq!(g.succs(0), vec![1]);
        assert_eq!(g.preds(0), Vec::<OpId>::new());
        let (p, s) = g.adjacency();
        assert_eq!(p[2], vec![1]);
        assert_eq!(s[1], vec![2]);
    }

    #[test]
    fn byte_accounting() {
        let g = tiny();
        assert_eq!(g.persistent_bytes(), 100);
        assert_eq!(g.dynamic_bytes(), 10 + 20 + 20 + 4);
        assert_eq!(g.activation_bytes(), 40);
    }

    #[test]
    fn replace_input_rewires_consumers() {
        let mut g = tiny();
        // Give op c a second tensor to switch to: a fresh input tensor.
        let alt = g.add_input_tensor("alt", 20, TensorClass::Activation);
        // c (op 2) consumes t2 (tensor 3); retarget it to alt.
        let n = g.replace_input(2, 3, alt);
        assert_eq!(n, 1);
        assert!(g.tensors[3].consumers.is_empty());
        assert_eq!(g.tensors[alt].consumers, vec![2]);
        assert!(g.ops[2].inputs.contains(&alt));
        assert!(validate::validate(&g).is_empty());
        // No-op replacement returns 0.
        assert_eq!(g.replace_input(2, 3, alt), 0);
    }

    #[test]
    fn control_input_registers_consumer() {
        let mut g = tiny();
        // Feed op b (op 1) an extra control input from the weight tensor.
        g.add_control_input(1, 0);
        assert!(g.ops[1].inputs.contains(&0));
        assert_eq!(g.tensors[0].consumers, vec![0, 1]);
        assert!(validate::validate(&g).is_empty());
    }

    #[test]
    fn consumers_registered() {
        let g = tiny();
        assert_eq!(g.tensors[0].consumers, vec![0]); // w consumed by op a
        assert_eq!(g.tensors[2].consumers, vec![1]); // t1 consumed by b
        assert!(g.tensors[4].is_output);
    }
}

//! Random training-graph generator for property-based tests.
//!
//! Generates small but *structurally training-like* DAGs: a forward chain
//! with random skip connections and fan-outs, a mirrored backward pass that
//! consumes forward activations, and per-parameter weight-update branches.
//! Every scheduler/layout invariant test in the repo sweeps over these.

use super::{Graph, OpKind, Phase, TensorClass};
use crate::util::Pcg64;

/// Knobs for the generator.
#[derive(Clone, Debug)]
pub struct RandomGraphCfg {
    /// Number of forward ops (total graph is ~3x this).
    pub fwd_ops: usize,
    /// Probability of an extra skip edge from an earlier activation.
    pub skip_p: f64,
    /// Probability that a forward op also emits a temp buffer.
    pub temp_p: f64,
    /// Max tensor size in bytes (sizes are uniform in [64, max]).
    pub max_size: u64,
    /// Fraction of forward ops that carry a trainable parameter.
    pub param_p: f64,
    /// Use Adam-style 3-buffer update branches (else single SGD op).
    pub adam: bool,
}

impl Default for RandomGraphCfg {
    fn default() -> Self {
        RandomGraphCfg {
            fwd_ops: 12,
            skip_p: 0.3,
            temp_p: 0.3,
            max_size: 4096,
            param_p: 0.5,
            adam: true,
        }
    }
}

/// Generate a random training graph.
pub fn random_training_graph(rng: &mut Pcg64, cfg: &RandomGraphCfg) -> Graph {
    let mut g = Graph::new("random");
    let sz = |rng: &mut Pcg64| 64 + rng.gen_range(cfg.max_size.max(65) - 64);

    let x = g.add_input_tensor("x", sz(rng), TensorClass::Input);

    // Forward chain with skips. Track (activation tensor, param tensor).
    let mut acts: Vec<usize> = vec![x];
    let mut params: Vec<(usize, usize)> = Vec::new(); // (param tensor, fwd op)
    for i in 0..cfg.fwd_ops {
        let mut inputs = vec![*acts.last().unwrap()];
        if acts.len() > 2 && rng.chance(cfg.skip_p) {
            let skip = acts[rng.usize_in(0, acts.len() - 1)];
            if !inputs.contains(&skip) {
                inputs.push(skip);
            }
        }
        let has_param = rng.chance(cfg.param_p);
        let w = if has_param {
            let w = g.add_input_tensor(format!("w{i}"), sz(rng), TensorClass::Weight);
            inputs.push(w);
            Some(w)
        } else {
            None
        };
        let mut outs = vec![(format!("act{i}"), sz(rng), TensorClass::Activation)];
        if rng.chance(cfg.temp_p) {
            outs.push((format!("tmp{i}"), sz(rng), TensorClass::TempBuffer));
        }
        let outs_ref: Vec<(&str, u64, TensorClass)> =
            outs.iter().map(|(n, s, c)| (n.as_str(), *s, *c)).collect();
        let (op, produced) = g.add_op(
            format!("fwd{i}"),
            OpKind::MatMul,
            Phase::Forward,
            &inputs,
            &outs_ref,
        );
        acts.push(produced[0]);
        if let Some(w) = w {
            params.push((w, op));
        }
    }

    // Loss.
    let (_, loss_out) = g.add_op(
        "loss",
        OpKind::Loss,
        Phase::Loss,
        &[*acts.last().unwrap()],
        &[("loss", 64, TensorClass::TempBuffer)],
    );
    let mut grad = loss_out[0];

    // Backward mirror: each bwd op consumes the corresponding activation
    // and the incoming gradient; parameterised ops also emit a weight grad.
    let mut wgrads: Vec<(usize, usize)> = Vec::new(); // (grad tensor, param tensor)
    for i in (0..cfg.fwd_ops).rev() {
        let act = acts[i + 1];
        let fwd_op = g.tensors[act].producer.unwrap();
        let has_param = params.iter().any(|&(_, op)| op == fwd_op);
        let mut outs = vec![(format!("dact{i}"), g.tensors[acts[i]].size, TensorClass::Gradient)];
        if has_param {
            let w = params.iter().find(|&&(_, op)| op == fwd_op).unwrap().0;
            outs.push((format!("dw{i}"), g.tensors[w].size, TensorClass::Gradient));
        }
        let outs_ref: Vec<(&str, u64, TensorClass)> =
            outs.iter().map(|(n, s, c)| (n.as_str(), *s, *c)).collect();
        let (_, produced) = g.add_op(
            format!("bwd{i}"),
            OpKind::MatMul,
            Phase::Backward,
            &[act, grad],
            &outs_ref,
        );
        grad = produced[0];
        if has_param {
            let w = params.iter().find(|&&(_, op)| op == fwd_op).unwrap().0;
            wgrads.push((produced[1], w));
        }
    }

    // Weight-update branches.
    for (k, &(dw, w)) in wgrads.iter().enumerate() {
        let wsize = g.tensors[w].size;
        if cfg.adam {
            let m = g.add_input_tensor(format!("adam_m{k}"), wsize, TensorClass::OptState);
            let v = g.add_input_tensor(format!("adam_v{k}"), wsize, TensorClass::OptState);
            // Fig 6 structure: a few temporaries then the in-place update.
            let (_, t1) = g.add_op(
                format!("adam_mul{k}"),
                OpKind::Elementwise,
                Phase::Update,
                &[dw, m],
                &[("t1", wsize, TensorClass::TempBuffer)],
            );
            let (_, t2) = g.add_op(
                format!("adam_sq{k}"),
                OpKind::Elementwise,
                Phase::Update,
                &[dw, v],
                &[("t2", wsize, TensorClass::TempBuffer)],
            );
            let (_, t3) = g.add_op(
                format!("adam_norm{k}"),
                OpKind::Elementwise,
                Phase::Update,
                &[t1[0], t2[0]],
                &[("t3", wsize, TensorClass::TempBuffer)],
            );
            let (_, out) = g.add_op(
                format!("adam_step{k}"),
                OpKind::OptimStep,
                Phase::Update,
                &[t3[0], w],
                &[("w_new", wsize, TensorClass::TempBuffer)],
            );
            g.mark_output(out[0]);
        } else {
            let (_, out) = g.add_op(
                format!("sgd_step{k}"),
                OpKind::OptimStep,
                Phase::Update,
                &[dw, w],
                &[("w_new", wsize, TensorClass::TempBuffer)],
            );
            g.mark_output(out[0]);
        }
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::validate::validate;
    use crate::util::quick::forall;

    #[test]
    fn random_graphs_are_valid() {
        forall("random graphs validate", 100, |rng| {
            let cfg = RandomGraphCfg {
                fwd_ops: rng.usize_in(2, 20),
                adam: rng.chance(0.5),
                ..Default::default()
            };
            let g = random_training_graph(rng, &cfg);
            let defects = validate(&g);
            if defects.is_empty() {
                Ok(())
            } else {
                Err(format!("{defects:?}"))
            }
        });
    }

    #[test]
    fn has_all_phases() {
        let mut rng = Pcg64::new(1);
        let g = random_training_graph(&mut rng, &RandomGraphCfg::default());
        use crate::graph::Phase::*;
        for ph in [Forward, Loss, Backward] {
            assert!(g.ops.iter().any(|o| o.phase == ph), "missing {ph:?}");
        }
    }
}

//! Topological orderings with pluggable tie-breaking.
//!
//! Kahn's algorithm where the choice among *ready* operators is the policy:
//! * [`program_order`] — lowest op id first. Model builders emit ops in
//!   definition order, so this reproduces **PyTorch**'s "execute in the
//!   order defined in the program" baseline (§I).
//! * [`ready_queue_order`] — FIFO by in-queue time, i.e. **TensorFlow**'s
//!   executor policy (§I).
//! * [`is_topological`] — validity check used by every test/invariant.

use super::{Graph, OpId};
use std::collections::VecDeque;

/// PyTorch baseline: among ready ops always pick the smallest op id
/// (= order of definition in the program).
pub fn program_order(g: &Graph) -> Vec<OpId> {
    let (preds, succs) = g.adjacency();
    let n = g.n_ops();
    let mut indeg: Vec<usize> = preds.iter().map(|p| p.len()).collect();
    // A binary heap of Reverse(id) would be O(log n); for clarity and
    // because n is ≤ ~2·10⁴ we use a sorted insertion-free scan via a
    // BinaryHeap on Reverse.
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let mut ready: BinaryHeap<Reverse<OpId>> = (0..n)
        .filter(|&v| indeg[v] == 0)
        .map(Reverse)
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(Reverse(v)) = ready.pop() {
        order.push(v);
        for &s in &succs[v] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(Reverse(s));
            }
        }
    }
    assert_eq!(order.len(), n, "graph has a cycle");
    order
}

/// Kahn's algorithm keyed by an arbitrary per-op priority: among ready
/// operators always pick the smallest `(pri[v], v)`. [`program_order`]
/// is the identity-priority case (kept separate as the allocation-free
/// hot path); the hybrid driver's warm-seed carry uses this to complete
/// a previous round's relative order onto an augmented graph.
pub fn priority_order(g: &Graph, pri: &[u64]) -> Vec<OpId> {
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;
    let (preds, succs) = g.adjacency();
    let n = g.n_ops();
    let mut indeg: Vec<usize> = preds.iter().map(|p| p.len()).collect();
    let mut ready: BinaryHeap<Reverse<(u64, OpId)>> = (0..n)
        .filter(|&v| indeg[v] == 0)
        .map(|v| Reverse((pri[v], v)))
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(Reverse((_, v))) = ready.pop() {
        order.push(v);
        for &s in &succs[v] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.push(Reverse((pri[s], s)));
            }
        }
    }
    assert_eq!(order.len(), n, "graph has a cycle");
    order
}

/// TensorFlow baseline: FIFO queue of ready operators ordered by the time
/// they became ready (ties broken by op id at initialisation).
pub fn ready_queue_order(g: &Graph) -> Vec<OpId> {
    let (preds, succs) = g.adjacency();
    let n = g.n_ops();
    let mut indeg: Vec<usize> = preds.iter().map(|p| p.len()).collect();
    let mut q: VecDeque<OpId> = (0..n).filter(|&v| indeg[v] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(v) = q.pop_front() {
        order.push(v);
        for &s in &succs[v] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                q.push_back(s);
            }
        }
    }
    assert_eq!(order.len(), n, "graph has a cycle");
    order
}

/// Check that `order` is a permutation of the ops respecting all edges.
pub fn is_topological(g: &Graph, order: &[OpId]) -> bool {
    if order.len() != g.n_ops() {
        return false;
    }
    let mut pos = vec![usize::MAX; g.n_ops()];
    for (i, &v) in order.iter().enumerate() {
        if v >= g.n_ops() || pos[v] != usize::MAX {
            return false; // out of range or duplicate
        }
        pos[v] = i;
    }
    for op in &g.ops {
        for p in g.preds(op.id) {
            if pos[p] >= pos[op.id] {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, Phase, TensorClass};

    /// Diamond: a -> {b, c} -> d.
    fn diamond() -> Graph {
        let mut g = Graph::new("diamond");
        let x = g.add_input_tensor("x", 8, TensorClass::Input);
        let (_, ta) = g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("ta", 8, TensorClass::Activation)]);
        let (_, tb) = g.add_op("b", OpKind::Other, Phase::Forward, &[ta[0]],
            &[("tb", 8, TensorClass::Activation)]);
        let (_, tc) = g.add_op("c", OpKind::Other, Phase::Forward, &[ta[0]],
            &[("tc", 8, TensorClass::Activation)]);
        g.add_op("d", OpKind::Other, Phase::Forward, &[tb[0], tc[0]],
            &[("td", 8, TensorClass::Activation)]);
        g
    }

    #[test]
    fn program_order_prefers_low_ids() {
        let g = diamond();
        assert_eq!(program_order(&g), vec![0, 1, 2, 3]);
    }

    #[test]
    fn ready_queue_is_valid() {
        let g = diamond();
        let o = ready_queue_order(&g);
        assert!(is_topological(&g, &o));
    }

    #[test]
    fn priority_order_respects_keys_within_dependences() {
        let g = diamond();
        // Identity priorities reproduce program order.
        let id_pri: Vec<u64> = (0..g.n_ops() as u64).collect();
        assert_eq!(priority_order(&g, &id_pri), program_order(&g));
        // Preferring c (op 2) over b (op 1) flips only that free choice.
        let o = priority_order(&g, &[0, 5, 1, 0]);
        assert!(is_topological(&g, &o));
        assert_eq!(o, vec![0, 2, 1, 3]);
    }

    #[test]
    fn is_topological_rejects_violations() {
        let g = diamond();
        assert!(is_topological(&g, &[0, 1, 2, 3]));
        assert!(!is_topological(&g, &[1, 0, 2, 3])); // b before a
        assert!(!is_topological(&g, &[0, 1, 2]));    // missing op
        assert!(!is_topological(&g, &[0, 1, 1, 3])); // duplicate
    }
}

//! Structural validation of graphs.
//!
//! Every producer of a `Graph` (model builders, the HLO parser, the random
//! generator used by property tests) runs through [`validate`] in its
//! tests; the planner calls it in debug builds before planning.

use super::Graph;

/// A structural defect in a graph.
#[derive(Debug, Clone, PartialEq)]
pub enum Defect {
    /// Tensor's `id` field doesn't match its index.
    TensorIdMismatch(usize),
    /// Op's `id` field doesn't match its index.
    OpIdMismatch(usize),
    /// Op references a tensor id out of range.
    DanglingTensorRef { op: usize, tensor: usize },
    /// Tensor lists a consumer that doesn't list it as input (or vice versa).
    InconsistentConsumer { tensor: usize, op: usize },
    /// Tensor producer doesn't list it as an output.
    InconsistentProducer { tensor: usize, op: usize },
    /// The op-level graph has a cycle.
    Cycle,
    /// A tensor has zero size (legal in HLO, but suspicious in builders).
    ZeroSize(usize),
    /// A `SwapOut`/`SwapIn` op violates the swap structural contract:
    /// `SwapOut` must consume ≥ 1 tensor and emit exactly one handle;
    /// `SwapIn` must emit exactly one tensor and consume a handle produced
    /// by a `SwapOut`.
    MalformedSwap { op: usize },
    /// A `Compress`/`Decompress` op violates the compression structural
    /// contract: `Compress` must consume ≥ 1 tensor and emit exactly one
    /// compressed representation; `Decompress` must emit exactly one
    /// tensor and consume a representation produced by a `Compress`.
    MalformedCompress { op: usize },
}

/// Validate; returns all defects found (empty = structurally sound).
///
/// Consumer consistency is checked by *multiplicity*, not mere membership:
/// a tensor listed `k` times in an op's inputs must list that op `k` times
/// in its consumers (and vice versa). Graph rewrites — control edges, the
/// recompute rewriter's consumer retargeting — rely on this to catch
/// half-applied edits that a containment check would let through.
pub fn validate(g: &Graph) -> Vec<Defect> {
    let mut defects = Vec::new();
    for (i, t) in g.tensors.iter().enumerate() {
        if t.id != i {
            defects.push(Defect::TensorIdMismatch(i));
        }
        if t.size == 0 {
            defects.push(Defect::ZeroSize(i));
        }
        if let Some(p) = t.producer {
            if p >= g.n_ops() {
                defects.push(Defect::DanglingTensorRef { op: p, tensor: i });
            } else if !g.ops[p].outputs.contains(&i) {
                defects.push(Defect::InconsistentProducer { tensor: i, op: p });
            }
        }
        let mut seen: Vec<usize> = Vec::new();
        for &c in &t.consumers {
            if c >= g.n_ops() {
                defects.push(Defect::DanglingTensorRef { op: c, tensor: i });
                continue;
            }
            if seen.contains(&c) {
                continue; // multiplicity already checked for this pair
            }
            seen.push(c);
            let in_consumers = t.consumers.iter().filter(|&&x| x == c).count();
            let in_inputs = g.ops[c].inputs.iter().filter(|&&x| x == i).count();
            if in_consumers != in_inputs {
                defects.push(Defect::InconsistentConsumer { tensor: i, op: c });
            }
        }
    }
    for (i, op) in g.ops.iter().enumerate() {
        if op.id != i {
            defects.push(Defect::OpIdMismatch(i));
        }
        for &t in op.inputs.iter().chain(op.outputs.iter()) {
            if t >= g.n_tensors() {
                defects.push(Defect::DanglingTensorRef { op: i, tensor: t });
            }
        }
        // Symmetric direction: an input the tensor doesn't know about at
        // all (zero consumer entries) escapes the tensor-side sweep above.
        for &t in &op.inputs {
            if t < g.n_tensors() && !g.tensors[t].consumers.contains(&i) {
                defects.push(Defect::InconsistentConsumer { tensor: t, op: i });
            }
        }
        // An op claiming an output the tensor attributes elsewhere.
        for &t in &op.outputs {
            if t < g.n_tensors() && g.tensors[t].producer != Some(i) {
                defects.push(Defect::InconsistentProducer { tensor: t, op: i });
            }
        }
        // Swap structural contract (the swap/ rewriter's invariants).
        match op.kind {
            super::OpKind::SwapOut => {
                if op.inputs.is_empty() || op.outputs.len() != 1 {
                    defects.push(Defect::MalformedSwap { op: i });
                }
            }
            super::OpKind::SwapIn => {
                let has_handle = op.inputs.iter().any(|&t| {
                    t < g.n_tensors()
                        && g.tensors[t]
                            .producer
                            .map(|p| g.ops[p].kind == super::OpKind::SwapOut)
                            .unwrap_or(false)
                });
                if op.outputs.len() != 1 || !has_handle {
                    defects.push(Defect::MalformedSwap { op: i });
                }
            }
            // Compression structural contract (the compress/ rewriter's
            // invariants, mirroring the swap pair).
            super::OpKind::Compress => {
                if op.inputs.is_empty() || op.outputs.len() != 1 {
                    defects.push(Defect::MalformedCompress { op: i });
                }
            }
            super::OpKind::Decompress => {
                let has_handle = op.inputs.iter().any(|&t| {
                    t < g.n_tensors()
                        && g.tensors[t]
                            .producer
                            .map(|p| g.ops[p].kind == super::OpKind::Compress)
                            .unwrap_or(false)
                });
                if op.outputs.len() != 1 || !has_handle {
                    defects.push(Defect::MalformedCompress { op: i });
                }
            }
            _ => {}
        }
    }
    // Cycle check: Kahn must visit everything.
    let (preds, succs) = g.adjacency();
    let mut indeg: Vec<usize> = preds.iter().map(|p| p.len()).collect();
    let mut stack: Vec<usize> = (0..g.n_ops()).filter(|&v| indeg[v] == 0).collect();
    let mut seen = 0;
    while let Some(v) = stack.pop() {
        seen += 1;
        for &s in &succs[v] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                stack.push(s);
            }
        }
    }
    if seen != g.n_ops() {
        defects.push(Defect::Cycle);
    }
    defects
}

/// Panic with a readable report if the graph is defective.
pub fn assert_valid(g: &Graph) {
    let d = validate(g);
    assert!(
        d.is_empty(),
        "graph '{}' has {} structural defects: {:?}",
        g.name,
        d.len(),
        &d[..d.len().min(10)]
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Graph, OpKind, Phase, TensorClass};

    #[test]
    fn clean_graph_validates() {
        let mut g = Graph::new("ok");
        let x = g.add_input_tensor("x", 4, TensorClass::Input);
        g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("t", 4, TensorClass::Activation)]);
        assert!(validate(&g).is_empty());
    }

    #[test]
    fn detects_zero_size() {
        let mut g = Graph::new("z");
        g.add_input_tensor("x", 0, TensorClass::Input);
        assert_eq!(validate(&g), vec![Defect::ZeroSize(0)]);
    }

    #[test]
    fn detects_inconsistent_consumer() {
        let mut g = Graph::new("bad");
        let x = g.add_input_tensor("x", 4, TensorClass::Input);
        g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("t", 4, TensorClass::Activation)]);
        // Corrupt: claim tensor 1 is consumed by op 0 without listing input.
        g.tensors[1].consumers.push(0);
        assert!(validate(&g)
            .iter()
            .any(|d| matches!(d, Defect::InconsistentConsumer { .. })));
    }

    #[test]
    fn detects_malformed_swap() {
        // A SwapIn whose input is not a SwapOut-produced handle.
        let mut g = Graph::new("swap-bad");
        let x = g.add_input_tensor("x", 4, TensorClass::Activation);
        g.add_op("si", OpKind::SwapIn, Phase::Backward, &[x],
            &[("t", 4, TensorClass::Activation)]);
        assert!(validate(&g)
            .iter()
            .any(|d| matches!(d, Defect::MalformedSwap { .. })));
        // A well-formed out/in pair validates cleanly.
        let mut g = Graph::new("swap-ok");
        let x = g.add_input_tensor("x", 4, TensorClass::Activation);
        let (_, h) = g.add_op("so", OpKind::SwapOut, Phase::Forward, &[x],
            &[("h", 1, TensorClass::TempBuffer)]);
        g.add_op("si", OpKind::SwapIn, Phase::Backward, &[h[0]],
            &[("t", 4, TensorClass::Activation)]);
        assert!(validate(&g).is_empty());
    }

    #[test]
    fn detects_malformed_compress() {
        // A Decompress whose input is not a Compress-produced tensor.
        let mut g = Graph::new("compress-bad");
        let x = g.add_input_tensor("x", 4, TensorClass::Activation);
        g.add_op("dc", OpKind::Decompress, Phase::Backward, &[x],
            &[("t", 4, TensorClass::Activation)]);
        assert!(validate(&g)
            .iter()
            .any(|d| matches!(d, Defect::MalformedCompress { .. })));
        // A well-formed compress/decompress pair validates cleanly.
        let mut g = Graph::new("compress-ok");
        let x = g.add_input_tensor("x", 4, TensorClass::Activation);
        let (_, h) = g.add_op("cp", OpKind::Compress, Phase::Forward, &[x],
            &[("h", 2, TensorClass::TempBuffer)]);
        g.add_op("dc", OpKind::Decompress, Phase::Backward, &[h[0]],
            &[("t", 4, TensorClass::Activation)]);
        assert!(validate(&g).is_empty());
    }

    #[test]
    fn detects_cycle() {
        let mut g = Graph::new("cyc");
        let x = g.add_input_tensor("x", 4, TensorClass::Input);
        let (a, t0) = g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("t0", 4, TensorClass::Activation)]);
        let (_b, t1) = g.add_op("b", OpKind::Other, Phase::Forward, &[t0[0]],
            &[("t1", 4, TensorClass::Activation)]);
        // Corrupt: feed b's output back into a.
        g.ops[a].inputs.push(t1[0]);
        g.tensors[t1[0]].consumers.push(a);
        assert!(validate(&g).contains(&Defect::Cycle));
    }
}

//! Transitive reachability, ASAP/ALAP schedules and comparability counts.
//!
//! These analyses drive two pillars of ROAM:
//!
//! * **Memory-insensitive operator detection** (§IV-A): an operator whose
//!   scheduling timestep is the same in *every* topological order is one
//!   that is comparable (ordered by precedence) with every other operator:
//!   `|pred*(v)| + |succ*(v)| = n - 1`. We compute transitive predecessor /
//!   successor sets with word-parallel bitset propagation.
//! * **`is_alive` estimation for the weight-update scheduler** (eq. 5): the
//!   paper derives liveness bounds "from the earliest possible execution
//!   time and the latest mandatory execution time of operators, which
//!   calculates the number of all transitive predecessors and successors" —
//!   i.e. ASAP(v) = |pred*(v)| and ALAP(v) = n - 1 - |succ*(v)| in a
//!   single-stream schedule.

use super::{Graph, OpId};
use crate::util::BitSet;

/// Transitive-closure data for a graph.
pub struct Reachability {
    /// `above[v]` = set of transitive predecessors of `v` (excluding `v`).
    pub above: Vec<BitSet>,
    /// `below[v]` = set of transitive successors of `v` (excluding `v`).
    pub below: Vec<BitSet>,
    /// A topological order used during construction.
    pub topo: Vec<OpId>,
}

impl Reachability {
    /// Compute both closures in O(n·m/64) words of work.
    ///
    /// Allocation discipline: besides the `2n` result rows (which the
    /// public representation requires), the propagation allocates exactly
    /// one scratch row and reuses it for every op by **double-buffering**:
    /// the accumulated union is built in the scratch (seeded word-parallel
    /// via [`BitSet::union_with_into`] / [`BitSet::copy_from`], so no
    /// clear pass is needed), then swapped with the destination row, whose
    /// zeroed words become the next scratch. The old code allocated a
    /// fresh n-bit accumulator per op — O(n²/8) bytes of allocator churn
    /// on GPT2-XL-sized graphs.
    pub fn compute(g: &Graph) -> Reachability {
        let n = g.n_ops();
        let topo = super::topo::program_order(g);
        let (preds, succs) = g.adjacency();
        let mut above: Vec<BitSet> = (0..n).map(|_| BitSet::new(n)).collect();
        let mut below: Vec<BitSet> = (0..n).map(|_| BitSet::new(n)).collect();
        let mut scratch = BitSet::new(n);

        // Forward pass in topo order: above[v] = ∪_{p∈preds(v)} above[p] ∪ {p}.
        for &v in &topo {
            accumulate(&mut above, v, &preds[v], &mut scratch);
        }
        // Backward pass in reverse topo order.
        for &v in topo.iter().rev() {
            accumulate(&mut below, v, &succs[v], &mut scratch);
        }
        Reachability { above, below, topo }
    }

    /// Number of ops this graph has.
    pub fn n(&self) -> usize {
        self.above.len()
    }

    /// Is `u` a strict transitive predecessor of `v`?
    pub fn precedes(&self, u: OpId, v: OpId) -> bool {
        self.above[v].get(u)
    }

    /// Are `u` and `v` comparable (one precedes the other)?
    pub fn comparable(&self, u: OpId, v: OpId) -> bool {
        u == v || self.precedes(u, v) || self.precedes(v, u)
    }

    /// Memory-insensitive test: `v` is ordered w.r.t. *every* other op, so
    /// its timestep is fixed across all topological orders (§IV-A).
    pub fn is_memory_insensitive(&self, v: OpId) -> bool {
        self.above[v].count() + self.below[v].count() == self.n() - 1
    }

    /// Earliest possible single-stream timestep of `v` (0-based):
    /// every transitive predecessor must run first.
    pub fn asap(&self, v: OpId) -> usize {
        self.above[v].count()
    }

    /// Latest mandatory single-stream timestep of `v` (0-based):
    /// all transitive successors must run after.
    pub fn alap(&self, v: OpId) -> usize {
        self.n() - 1 - self.below[v].count()
    }
}

/// `rows[v] = (∪_{u ∈ seeds} rows[u] ∪ {u})`, built in `scratch` and
/// swapped into place. Every word of the scratch is overwritten by the
/// seeding step, so the buffer needs no clearing between ops; the swapped-
/// out destination row (freshly constructed, all zero) becomes the next
/// scratch. Rows of `seeds` are fully computed before `v` because callers
/// iterate in (reverse) topological order, and `v ∉ seeds` in a DAG, so
/// reading `rows[u]` while writing `scratch` never aliases.
fn accumulate(rows: &mut [BitSet], v: OpId, seeds: &[OpId], scratch: &mut BitSet) {
    match seeds {
        [] => {} // rows[v] is already the empty set
        [u] => {
            let u = *u;
            scratch.copy_from(&rows[u]);
            scratch.set(u);
            std::mem::swap(&mut rows[v], scratch);
        }
        [u0, u1, rest @ ..] => {
            let (u0, u1) = (*u0, *u1);
            rows[u0].union_with_into(&rows[u1], scratch);
            scratch.set(u0);
            scratch.set(u1);
            for &u in rest {
                scratch.union_with(&rows[u]);
                scratch.set(u);
            }
            std::mem::swap(&mut rows[v], scratch);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, Phase, TensorClass};

    /// chain a->b->c with a side branch a->d->c  (b,d incomparable).
    fn braid() -> Graph {
        let mut g = Graph::new("braid");
        let x = g.add_input_tensor("x", 1, TensorClass::Input);
        let (_, ta) = g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("ta", 1, TensorClass::Activation)]);
        let (_, tb) = g.add_op("b", OpKind::Other, Phase::Forward, &[ta[0]],
            &[("tb", 1, TensorClass::Activation)]);
        let (_, td) = g.add_op("d", OpKind::Other, Phase::Forward, &[ta[0]],
            &[("td", 1, TensorClass::Activation)]);
        g.add_op("c", OpKind::Other, Phase::Forward, &[tb[0], td[0]],
            &[("tc", 1, TensorClass::Activation)]);
        g
    }

    #[test]
    fn closures() {
        let g = braid();
        let r = Reachability::compute(&g);
        assert!(r.precedes(0, 3));
        assert!(r.precedes(1, 3));
        assert!(!r.precedes(1, 2)); // b and d incomparable
        assert!(!r.comparable(1, 2));
        assert!(r.comparable(0, 3));
    }

    #[test]
    fn memory_insensitive_ops() {
        let g = braid();
        let r = Reachability::compute(&g);
        assert!(r.is_memory_insensitive(0)); // a: before everything
        assert!(r.is_memory_insensitive(3)); // c: after everything
        assert!(!r.is_memory_insensitive(1)); // b floats against d
        assert!(!r.is_memory_insensitive(2));
    }

    #[test]
    fn asap_alap_bounds() {
        let g = braid();
        let r = Reachability::compute(&g);
        assert_eq!(r.asap(0), 0);
        assert_eq!(r.alap(0), 0); // must be first
        assert_eq!(r.asap(3), 3);
        assert_eq!(r.alap(3), 3); // must be last
        assert_eq!(r.asap(1), 1);
        assert_eq!(r.alap(1), 2); // b can be step 1 or 2
        assert!(r.asap(2) <= r.alap(2));
    }

    #[test]
    fn chain_all_insensitive() {
        let mut g = Graph::new("chain");
        let mut prev = g.add_input_tensor("x", 1, TensorClass::Input);
        for i in 0..5 {
            let (_, t) = g.add_op(format!("op{i}"), OpKind::Other, Phase::Forward,
                &[prev], &[("t", 1, TensorClass::Activation)]);
            prev = t[0];
        }
        let r = Reachability::compute(&g);
        for v in 0..5 {
            assert!(r.is_memory_insensitive(v));
            assert_eq!(r.asap(v), v);
            assert_eq!(r.alap(v), v);
        }
    }
}

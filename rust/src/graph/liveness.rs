//! Tensor lifetime computation.
//!
//! Given a schedule (a timestep per operator — positions of a single-stream
//! order, or a multi-stream assignment where several ops share a timestep),
//! each tensor gets a closed interval `[birth, death]` of timesteps during
//! which it occupies memory:
//!
//! * `birth` = timestep of the producer (0 for graph inputs),
//! * `death` = max timestep over consumers; producers' own timestep when
//!   there is no consumer; the horizon when the tensor is a graph output.
//!
//! Persistent tensors (weights / optimizer state) are assigned the full
//! `[0, horizon]` interval — they are excluded from arena planning but the
//! interval keeps the simulators honest if they are included.

use super::{Graph, OpId, TensorId};

/// Closed interval of timesteps a tensor is resident.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Lifetime {
    pub birth: usize,
    pub death: usize,
}

impl Lifetime {
    /// Do two lifetimes overlap (share at least one timestep)?
    #[inline]
    pub fn overlaps(&self, other: &Lifetime) -> bool {
        self.birth <= other.death && other.birth <= self.death
    }

    /// Interval length in timesteps.
    pub fn len(&self) -> usize {
        self.death - self.birth + 1
    }

    pub fn is_empty(&self) -> bool {
        false // closed intervals are never empty
    }
}

/// Compute lifetimes for every tensor under the timestep assignment `ts`
/// (one entry per op). `horizon` is the last timestep (usually
/// `max(ts)`); outputs and persistents live until it.
pub fn lifetimes_with_horizon(g: &Graph, ts: &[usize], horizon: usize) -> Vec<Lifetime> {
    assert_eq!(ts.len(), g.n_ops());
    g.tensors
        .iter()
        .map(|t| {
            if t.class.is_persistent() {
                return Lifetime {
                    birth: 0,
                    death: horizon,
                };
            }
            let birth = t.producer.map(|p| ts[p]).unwrap_or(0);
            let mut death = t.consumers.iter().map(|&c| ts[c]).max().unwrap_or(birth);
            if t.is_output {
                death = horizon;
            }
            debug_assert!(death >= birth, "consumer scheduled before producer");
            Lifetime { birth, death }
        })
        .collect()
}

/// Lifetimes under a timestep assignment, horizon = max timestep.
pub fn lifetimes(g: &Graph, ts: &[usize]) -> Vec<Lifetime> {
    let horizon = ts.iter().copied().max().unwrap_or(0);
    lifetimes_with_horizon(g, ts, horizon)
}

/// Convert a single-stream order (permutation of ops) into a timestep
/// assignment (`ts[op] = position in the order`).
pub fn order_to_timesteps(order: &[OpId]) -> Vec<usize> {
    let mut ts = vec![usize::MAX; order.len()];
    for (pos, &v) in order.iter().enumerate() {
        ts[v] = pos;
    }
    debug_assert!(ts.iter().all(|&t| t != usize::MAX), "order not a permutation");
    ts
}

/// Ids of dynamic (non-persistent) tensors — the set the planner places.
pub fn dynamic_tensors(g: &Graph) -> Vec<TensorId> {
    g.tensors
        .iter()
        .filter(|t| !t.class.is_persistent())
        .map(|t| t.id)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, Phase, TensorClass};

    fn chain3() -> Graph {
        // a -> t0 -> b -> t1 -> c, weight w into a, t_loss output of c.
        let mut g = Graph::new("c3");
        let w = g.add_input_tensor("w", 100, TensorClass::Weight);
        let x = g.add_input_tensor("x", 10, TensorClass::Input);
        let (_, t0) = g.add_op("a", OpKind::Other, Phase::Forward, &[w, x],
            &[("t0", 5, TensorClass::Activation)]);
        let (_, t1) = g.add_op("b", OpKind::Other, Phase::Forward, &[t0[0]],
            &[("t1", 6, TensorClass::Activation)]);
        let (_, t2) = g.add_op("c", OpKind::Other, Phase::Loss, &[t1[0]],
            &[("loss", 4, TensorClass::TempBuffer)]);
        g.mark_output(t2[0]);
        g
    }

    #[test]
    fn basic_lifetimes() {
        let g = chain3();
        let ts = order_to_timesteps(&[0, 1, 2]);
        let lt = lifetimes(&g, &ts);
        // w: persistent, full horizon.
        assert_eq!(lt[0], Lifetime { birth: 0, death: 2 });
        // x: input, consumed by op a at t=0.
        assert_eq!(lt[1], Lifetime { birth: 0, death: 0 });
        // t0: born t=0 (a), dies t=1 (b).
        assert_eq!(lt[2], Lifetime { birth: 0, death: 1 });
        // t1: born 1, dies 2.
        assert_eq!(lt[3], Lifetime { birth: 1, death: 2 });
        // loss: output → lives to horizon.
        assert_eq!(lt[4], Lifetime { birth: 2, death: 2 });
    }

    #[test]
    fn overlap_predicate() {
        let a = Lifetime { birth: 0, death: 3 };
        let b = Lifetime { birth: 3, death: 5 };
        let c = Lifetime { birth: 4, death: 6 };
        assert!(a.overlaps(&b)); // touch at 3
        assert!(!a.overlaps(&c));
        assert!(b.overlaps(&c));
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn multi_stream_shared_timestep() {
        let g = chain3();
        // b and c crammed into the same timestep is invalid for chain3
        // (c consumes b's output), but a two-stream assignment where a is
        // at 0 and b at 1, c at 1 would break producer<consumer; instead
        // test a legal MS assignment identical to SS here.
        let lt = lifetimes(&g, &[0, 1, 2]);
        assert_eq!(lt.len(), g.n_tensors());
    }

    #[test]
    fn no_consumer_dies_at_birth() {
        let mut g = Graph::new("dead");
        let x = g.add_input_tensor("x", 1, TensorClass::Input);
        g.add_op("a", OpKind::Other, Phase::Forward, &[x],
            &[("dead", 7, TensorClass::TempBuffer)]);
        let lt = lifetimes(&g, &[0]);
        assert_eq!(lt[1], Lifetime { birth: 0, death: 0 });
    }

    #[test]
    fn dynamic_tensor_filter() {
        let g = chain3();
        let dy = dynamic_tensors(&g);
        assert_eq!(dy, vec![1, 2, 3, 4]); // everything but the weight
    }
}

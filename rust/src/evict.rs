//! Shared eviction machinery for the high-level memory techniques.
//!
//! Budgeted rematerialization ([`crate::recompute`]), bandwidth-aware
//! offloading ([`crate::swap`]) and in-place compression
//! ([`crate::compress`]) all follow the same structural recipe: pick a
//! forward activation with backward consumers, *evict* it (retarget its
//! backward consumers to a replacement tensor produced inside the
//! backward pass) and let the liveness rules price the saving — the
//! original now dies at its last forward use. The techniques only differ
//! in how the replacement is produced (cloned forward ops, a `SwapIn`
//! fetch, or a `Decompress`) and in what overhead that costs (FLOP-proxy
//! bytes, un-hidden transfer time, or codec seconds).
//!
//! This module owns the pieces that recipe shares:
//!
//! * [`is_evictable`] — the eligibility gate;
//! * [`filter_evictable`] — dedup + eligibility filtering of a requested
//!   eviction set;
//! * [`backward_consumers`] / [`retarget_backward`] — the consumer-edge
//!   rewrite both rewriters perform;
//! * [`find_anchor`] — the loss-phase control anchor that pins replacement
//!   producers into the backward region for any topological scheduler.

use crate::graph::{Graph, OpId, Phase, Reachability, TensorClass, TensorId};

/// Can `t` be evicted (recomputed *or* swapped)? It must be a non-output
/// forward activation with at least one backward consumer, and no
/// loss/update consumers (those pin it across the fwd/bwd boundary
/// anyway). Tensors introduced by earlier rewrites are excluded
/// structurally: swap handles are temp buffers, and replacement tensors
/// are produced by backward-phase ops.
pub fn is_evictable(g: &Graph, t: TensorId) -> bool {
    let tt = &g.tensors[t];
    if tt.class != TensorClass::Activation || tt.is_output {
        return false;
    }
    let Some(p) = tt.producer else {
        return false;
    };
    if g.ops[p].phase != Phase::Forward {
        return false;
    }
    let mut has_bwd = false;
    for &c in &tt.consumers {
        match g.ops[c].phase {
            Phase::Backward => has_bwd = true,
            Phase::Forward => {}
            Phase::Loss | Phase::Update => return false,
        }
    }
    has_bwd
}

/// Deduplicate `evict` (first occurrence wins) and drop everything
/// [`is_evictable`] rejects, preserving order.
pub fn filter_evictable(g: &Graph, evict: &[TensorId]) -> Vec<TensorId> {
    let mut seen = vec![false; g.n_tensors()];
    let mut out = Vec::new();
    for &t in evict {
        if t < g.n_tensors() && !seen[t] && is_evictable(g, t) {
            seen[t] = true;
            out.push(t);
        }
    }
    out
}

/// The backward-phase consumers of `t` in `g`, sorted and dedup'd.
pub fn backward_consumers(g: &Graph, t: TensorId) -> Vec<OpId> {
    let mut consumers: Vec<OpId> = g.tensors[t]
        .consumers
        .iter()
        .copied()
        .filter(|&c| g.ops[c].phase == Phase::Backward)
        .collect();
    consumers.sort_unstable();
    consumers.dedup();
    consumers
}

/// Retarget every backward consumer `t` has in `g` from `t` to
/// `replacement` inside `out` (an augmented copy of `g` in which both
/// tensors exist). Returns the retargeted ops.
pub fn retarget_backward(
    out: &mut Graph,
    g: &Graph,
    t: TensorId,
    replacement: TensorId,
) -> Vec<OpId> {
    let consumers = backward_consumers(g, t);
    for &c in &consumers {
        out.replace_input(c, t, replacement);
    }
    consumers
}

/// An output tensor of a loss-phase op that precedes every retargeted
/// backward consumer, if one exists. Used as a control input for the
/// replacement producers: acyclic by construction — the anchor strictly
/// precedes all replacement-output consumers, and the replacement ops have
/// no other successors, so no path can lead back to the anchor.
pub fn find_anchor(
    g: &Graph,
    reach: &Reachability,
    remap: &[(TensorId, TensorId)],
) -> Option<TensorId> {
    let mut rewired: Vec<OpId> = remap
        .iter()
        .flat_map(|&(t, _)| backward_consumers(g, t))
        .collect();
    rewired.sort_unstable();
    rewired.dedup();
    g.ops
        .iter()
        .find(|op| {
            op.phase == Phase::Loss
                && !op.outputs.is_empty()
                && rewired.iter().all(|&c| reach.precedes(op.id, c))
        })
        .map(|op| op.outputs[0])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, Phase, TensorClass};

    /// fwd chain a→b→loss, backward consumes both activations.
    fn training_chain() -> Graph {
        let mut g = Graph::new("chain");
        let x = g.add_input_tensor("x", 10, TensorClass::Input);
        let (_, t0) = g.add_op(
            "a",
            OpKind::MatMul,
            Phase::Forward,
            &[x],
            &[("act0", 100, TensorClass::Activation)],
        );
        let (_, t1) = g.add_op(
            "b",
            OpKind::MatMul,
            Phase::Forward,
            &[t0[0]],
            &[("act1", 100, TensorClass::Activation)],
        );
        let (_, l) = g.add_op(
            "loss",
            OpKind::Loss,
            Phase::Loss,
            &[t1[0]],
            &[("loss", 4, TensorClass::TempBuffer)],
        );
        g.mark_output(l[0]);
        let (_, d1) = g.add_op(
            "b.bwd",
            OpKind::MatMul,
            Phase::Backward,
            &[t1[0], l[0]],
            &[("dact0", 100, TensorClass::Gradient)],
        );
        let (_, d0) = g.add_op(
            "a.bwd",
            OpKind::MatMul,
            Phase::Backward,
            &[t0[0], d1[0]],
            &[("dx", 10, TensorClass::Gradient)],
        );
        g.mark_output(d0[0]);
        g
    }

    #[test]
    fn evictability_rules() {
        let g = training_chain();
        assert!(is_evictable(&g, 1)); // act0: fwd activation, bwd consumer
        assert!(!is_evictable(&g, 2)); // act1: loss consumer pins it
        assert!(!is_evictable(&g, 0)); // graph input
        assert!(!is_evictable(&g, 3)); // loss output (TempBuffer + output)
    }

    #[test]
    fn filter_dedups_and_rejects() {
        let g = training_chain();
        assert_eq!(filter_evictable(&g, &[1, 1, 2, 0, 99]), vec![1]);
        assert!(filter_evictable(&g, &[]).is_empty());
    }

    #[test]
    fn backward_consumer_listing() {
        let g = training_chain();
        assert_eq!(backward_consumers(&g, 1), vec![4]); // act0 → a.bwd
        assert_eq!(backward_consumers(&g, 0), Vec::<OpId>::new());
    }

    #[test]
    fn anchor_is_the_loss_output() {
        let g = training_chain();
        let reach = Reachability::compute(&g);
        // act0's backward consumer (a.bwd) is preceded by the loss op.
        assert_eq!(find_anchor(&g, &reach, &[(1, 0)]), Some(3));
        assert_eq!(find_anchor(&g, &reach, &[]), Some(3)); // vacuous
    }
}
